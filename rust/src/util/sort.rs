//! The sort engine: stable LSD radix argsort on u64 curve keys, a
//! parallel sample-sort driver over the [`Coordinator`] workers, and
//! the k-way [`LoserTree`] the store's streaming segment merge runs on.
//!
//! Every data structure in this reproduction is built by putting rows
//! in curve order — [`SfcIndex`](crate::index::SfcIndex) builds, store
//! ingest and compaction, grid cell ranking, k-means sharding — so this
//! module is the shared back half of all of them:
//! [`crate::curves::ndim::sfc_argsort`] and friends route through
//! [`stable_argsort`], which picks a substrate by input size and
//! available parallelism (see [`sort_path`]).
//!
//! ## Stability invariant
//!
//! Every path returns **bit-for-bit the same permutation** as the
//! stable comparison argsort ([`comparison_argsort`]): equal keys keep
//! their input order. For the radix sort this holds by construction
//! (each counting pass scatters in input order); for the sample sort it
//! holds because the splitter rule assigns *all* occurrences of a key
//! to one bucket (`partition_point(splitters, s <= key)`), the
//! chunk-partitioned scatter preserves input order inside each bucket
//! (chunks are claimed through the dynamic
//! [`ChunkQueue`](crate::coordinator) but reassembled in chunk order),
//! and the per-bucket sort is the stable radix sort — so ties can never
//! straddle a bucket boundary and no cross-boundary repair is needed at
//! emit time. The property tests in `tests/sort.rs` assert this across
//! duplicate-heavy corpora for every path and thread count.

use crate::coordinator::Coordinator;

/// Inputs shorter than this use the plain comparison sort — the radix
/// passes' histogram setup costs more than sorting a handful of keys.
pub const RADIX_MIN_KEYS: usize = 128;

/// Inputs shorter than this never fan out across threads: below it the
/// scatter/merge bookkeeping beats the win from parallel bucket sorts.
pub const PAR_MIN_KEYS: usize = 1 << 16;

/// Which argsort substrate a key column of a given size runs on —
/// fast-path introspection mirroring
/// [`KeyPath`](crate::curves::fastkey::KeyPath) and
/// [`NeighborPath`](crate::curves::neighbor::NeighborPath).
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum SortPath {
    /// `sort_by_key` on the index column (reference semantics; tiny
    /// inputs only).
    Comparison,
    /// Single-threaded stable LSD radix sort, byte at a time.
    RadixLsd,
    /// Parallel sample sort: sampled splitters, chunk-partitioned
    /// bucket scatter, per-bucket stable radix sort.
    SampleSort,
}

impl SortPath {
    /// Short label for reports.
    pub fn name(self) -> &'static str {
        match self {
            SortPath::Comparison => "comparison",
            SortPath::RadixLsd => "radix-lsd",
            SortPath::SampleSort => "sample-sort",
        }
    }

    /// True for every path except the comparison fallback.
    pub fn is_fast(self) -> bool {
        self != SortPath::Comparison
    }
}

/// Path [`stable_argsort_threads`] selects for `n` keys at `threads`
/// workers. Pure — tests assert selection without sorting anything.
pub fn sort_path(n: usize, threads: usize) -> SortPath {
    if n < RADIX_MIN_KEYS {
        SortPath::Comparison
    } else if threads > 1 && n >= PAR_MIN_KEYS {
        SortPath::SampleSort
    } else {
        SortPath::RadixLsd
    }
}

/// Worker count the auto-selecting [`stable_argsort`] fans out to: one
/// per available core (cached after the first call).
pub fn default_threads() -> usize {
    static THREADS: std::sync::OnceLock<usize> = std::sync::OnceLock::new();
    *THREADS
        .get_or_init(|| std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1))
}

/// Stable argsort of a key column: `order[pos]` is the input index of
/// the `pos`-th smallest key (ties keep input order). Auto-selects the
/// substrate by [`sort_path`] under [`default_threads`]; every choice
/// returns the identical permutation.
pub fn stable_argsort(keys: &[u64]) -> Vec<u32> {
    stable_argsort_threads(keys, default_threads())
}

/// [`stable_argsort`] with an explicit worker budget (`threads <= 1`
/// stays serial). The permutation is independent of `threads`.
pub fn stable_argsort_threads(keys: &[u64], threads: usize) -> Vec<u32> {
    match sort_path(keys.len(), threads) {
        SortPath::Comparison => comparison_argsort(keys),
        SortPath::RadixLsd => radix_argsort(keys),
        SortPath::SampleSort => sample_argsort(keys, &Coordinator::new(threads)),
    }
}

/// The reference substrate: a stable comparison sort on the index
/// column. Every other path must match it bit-for-bit.
pub fn comparison_argsort(keys: &[u64]) -> Vec<u32> {
    let mut order: Vec<u32> = (0..keys.len() as u32).collect();
    order.sort_by_key(|&idx| keys[idx as usize]);
    order
}

/// Stable LSD radix argsort: one shared histogram pass builds all eight
/// per-byte counts, then a counting-scatter pass per *non-constant*
/// byte (a byte every key agrees on is skipped — curve keys at modest
/// `dims·level` leave their high bytes zero, so typical columns take
/// 3–5 passes, not 8). Keys travel with their indices so every pass
/// streams sequentially. Stability: scatter walks the input in order.
pub fn radix_argsort(keys: &[u64]) -> Vec<u32> {
    let mut k = keys.to_vec();
    let mut idx: Vec<u32> = (0..keys.len() as u32).collect();
    radix_sort_pairs(&mut k, &mut idx);
    idx
}

/// Sort `keys` and carry `idx` along (parallel arrays). The in-place
/// core shared by [`radix_argsort`] and the sample sort's per-bucket
/// stage.
fn radix_sort_pairs(keys: &mut Vec<u64>, idx: &mut Vec<u32>) {
    let n = keys.len();
    debug_assert_eq!(n, idx.len());
    assert!(n <= u32::MAX as usize, "radix argsort indexes with u32");
    if n <= 1 {
        return;
    }
    // One pass over the column fills all eight byte histograms (8 KiB).
    let mut hist = [[0u32; 256]; 8];
    for &k in keys.iter() {
        for (b, h) in hist.iter_mut().enumerate() {
            h[((k >> (8 * b)) & 0xFF) as usize] += 1;
        }
    }
    let mut key_tmp = vec![0u64; n];
    let mut idx_tmp = vec![0u32; n];
    for (b, h) in hist.iter().enumerate() {
        if h.iter().any(|&c| c as usize == n) {
            continue; // constant byte: the pass would be the identity
        }
        let mut offs = [0u32; 256];
        let mut sum = 0u32;
        for (o, &c) in offs.iter_mut().zip(h.iter()) {
            *o = sum;
            sum += c;
        }
        for (&k, &ix) in keys.iter().zip(idx.iter()) {
            let v = ((k >> (8 * b)) & 0xFF) as usize;
            let dst = offs[v] as usize;
            offs[v] += 1;
            key_tmp[dst] = k;
            idx_tmp[dst] = ix;
        }
        std::mem::swap(keys, &mut key_tmp);
        std::mem::swap(idx, &mut idx_tmp);
    }
}

/// Parallel sample-sort argsort over the coordinator's workers:
///
/// 1. **Splitters** — a deterministic stride sample of the key column
///    (16× oversampled), sorted; bucket fences at its quantiles.
/// 2. **Scatter** — the input is cut into chunks handed out through
///    [`Coordinator::par_map`]'s dynamic queue; each chunk partitions
///    its keys into per-bucket index lists (equal keys always land in
///    the same bucket, so ties never cross a boundary).
/// 3. **Bucket sort** — one task per bucket concatenates its chunk
///    lists *in chunk order* (restoring global input order within the
///    bucket) and runs the stable radix sort on the gathered keys.
/// 4. **Concatenate** — bucket outputs, in bucket order, are the final
///    permutation.
///
/// Falls back to [`radix_argsort`] below [`PAR_MIN_KEYS`] or at one
/// worker. The result is bit-for-bit [`comparison_argsort`]'s
/// permutation for any thread count.
pub fn sample_argsort(keys: &[u64], coord: &Coordinator) -> Vec<u32> {
    let n = keys.len();
    let threads = coord.threads();
    if threads <= 1 || n < PAR_MIN_KEYS {
        return radix_argsort(keys);
    }
    assert!(n <= u32::MAX as usize, "sample argsort indexes with u32");
    let buckets = (threads * 4).min(256);
    let sample_n = (buckets * 16).min(n);
    let mut sample: Vec<u64> = (0..sample_n).map(|i| keys[i * n / sample_n]).collect();
    sample.sort_unstable();
    let splitters: Vec<u64> = (1..buckets).map(|j| sample[j * sample_n / buckets]).collect();
    // Chunk descriptors in input order; par_map returns per-chunk
    // results in the same order, which is what keeps the scatter stable.
    let chunk = n.div_ceil(threads * 4).max(1);
    let chunks: Vec<(usize, usize)> =
        (0..n).step_by(chunk).map(|s| (s, (s + chunk).min(n))).collect();
    let scattered: Vec<Vec<Vec<u32>>> = coord.par_map(&chunks, |_, &(start, end)| {
        let mut local: Vec<Vec<u32>> = vec![Vec::new(); buckets];
        for (i, &k) in keys[start..end].iter().enumerate() {
            let b = splitters.partition_point(|&s| s <= k);
            local[b].push((start + i) as u32);
        }
        local
    });
    let bucket_ids: Vec<usize> = (0..buckets).collect();
    let sorted: Vec<Vec<u32>> = coord.par_map(&bucket_ids, |_, &b| {
        let mut idx: Vec<u32> = Vec::new();
        for chunk_out in &scattered {
            idx.extend_from_slice(&chunk_out[b]);
        }
        let mut bkeys: Vec<u64> = idx.iter().map(|&i| keys[i as usize]).collect();
        radix_sort_pairs(&mut bkeys, &mut idx);
        idx
    });
    let mut out = Vec::with_capacity(n);
    for b in &sorted {
        out.extend_from_slice(b);
    }
    out
}

// ---------------------------------------------------------------------------
// Loser tree
// ---------------------------------------------------------------------------

/// Tournament loser tree for k-way streaming merges: holds one current
/// key per input run (leaf), answers the global minimum in O(1) and
/// replaces the winning leaf's key in O(log k) — the classic structure
/// behind [`Segment::merge`](crate::index::store::segment::Segment::merge)'s
/// streaming path.
///
/// ```text
///            tree[0] ── overall winner (leaf index)
///               │
///            tree[1] ── loser of the final
///            /     \
///      tree[2]     tree[3] ── losers of the semifinals
///       /   \       /   \
///     L0    L1    L2    L3 ── leaves: current key per run (None = done)
/// ```
///
/// Ties break toward the **lower leaf index** (deterministic — the
/// merge feeds parts in a fixed order), and exhausted leaves (`None`)
/// always lose.
pub struct LoserTree<K: Ord + Copy> {
    /// `tree[0]`: the overall winner's leaf; `tree[1..m]`: the loser
    /// leaf of each internal match.
    tree: Vec<u32>,
    /// Current key per (padded) leaf; `None` = exhausted.
    keys: Vec<Option<K>>,
    /// Padded leaf count (power of two).
    m: usize,
}

impl<K: Ord + Copy> LoserTree<K> {
    /// Build over the initial per-run heads (index in the vec = leaf
    /// index handed back by [`LoserTree::winner`]).
    pub fn new(leaves: Vec<Option<K>>) -> Self {
        let k = leaves.len().max(1);
        let m = k.next_power_of_two();
        let mut keys = leaves;
        keys.resize(m, None);
        // Bottom-up: play every match once, recording winners up and
        // losers into the nodes.
        let mut win: Vec<u32> = vec![0; 2 * m];
        for (p, w) in win.iter_mut().enumerate().skip(m) {
            *w = (p - m) as u32;
        }
        let mut tree = vec![0u32; m];
        for p in (1..m).rev() {
            let (a, b) = (win[2 * p], win[2 * p + 1]);
            let (w, l) = if Self::beats(&keys, a, b) { (a, b) } else { (b, a) };
            win[p] = w;
            tree[p] = l;
        }
        tree[0] = win[1];
        LoserTree { tree, keys, m }
    }

    /// True when leaf `a` beats leaf `b`: smaller `(key, leaf)` wins,
    /// exhausted leaves always lose.
    fn beats(keys: &[Option<K>], a: u32, b: u32) -> bool {
        match (keys[a as usize], keys[b as usize]) {
            (Some(ka), Some(kb)) => (ka, a) < (kb, b),
            (Some(_), None) => true,
            (None, _) => false,
        }
    }

    /// The current minimum across all runs as `(leaf, key)`, or `None`
    /// once every leaf is exhausted.
    pub fn winner(&self) -> Option<(usize, K)> {
        let w = self.tree[0] as usize;
        self.keys[w].map(|k| (w, k))
    }

    /// Replace `leaf`'s key with the run's next head (`None` =
    /// exhausted) and replay its path to the root.
    pub fn replace(&mut self, leaf: usize, key: Option<K>) {
        self.keys[leaf] = key;
        let mut winner = leaf as u32;
        let mut node = (leaf + self.m) / 2;
        while node >= 1 {
            let other = self.tree[node];
            if !Self::beats(&self.keys, winner, other) {
                self.tree[node] = winner;
                winner = other;
            }
            node /= 2;
        }
        self.tree[0] = winner;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn corpora(rng: &mut Rng, n: usize) -> Vec<Vec<u64>> {
        let mut out = vec![
            (0..n).map(|_| rng.next_u64()).collect::<Vec<u64>>(),
            (0..n).map(|_| rng.below(8)).collect(),
            vec![7u64; n],
        ];
        let mut sorted: Vec<u64> = (0..n).map(|_| rng.below(1000)).collect();
        sorted.sort_unstable();
        out.push(sorted.clone());
        sorted.reverse();
        out.push(sorted);
        out
    }

    #[test]
    fn radix_and_sample_match_comparison_bit_for_bit() {
        let mut rng = Rng::new(8);
        for n in [0usize, 1, 2, 100, 5000, (1 << 16) + 17] {
            for keys in corpora(&mut rng, n) {
                let want = comparison_argsort(&keys);
                assert_eq!(radix_argsort(&keys), want, "radix n={n}");
                for t in [1usize, 2, 5, 8] {
                    let got = sample_argsort(&keys, &Coordinator::new(t));
                    assert_eq!(got, want, "sample t={t} n={n}");
                }
            }
        }
    }

    #[test]
    fn path_selection_is_size_and_thread_aware() {
        assert_eq!(sort_path(10, 8), SortPath::Comparison);
        assert_eq!(sort_path(RADIX_MIN_KEYS, 1), SortPath::RadixLsd);
        assert_eq!(sort_path(PAR_MIN_KEYS - 1, 8), SortPath::RadixLsd);
        assert_eq!(sort_path(PAR_MIN_KEYS, 8), SortPath::SampleSort);
        assert_eq!(sort_path(PAR_MIN_KEYS, 1), SortPath::RadixLsd);
        assert!(!sort_path(10, 8).is_fast());
        assert!(sort_path(1 << 20, 8).is_fast());
    }

    #[test]
    fn loser_tree_merges_sorted_runs() {
        let mut rng = Rng::new(3);
        for k in [1usize, 2, 3, 5, 8] {
            let runs: Vec<Vec<u64>> = (0..k)
                .map(|_| {
                    let mut r: Vec<u64> =
                        (0..rng.below(40)).map(|_| rng.below(100)).collect();
                    r.sort_unstable();
                    r
                })
                .collect();
            let mut want: Vec<u64> = runs.iter().flatten().copied().collect();
            want.sort_unstable();
            let mut cursors = vec![0usize; k];
            let heads: Vec<Option<u64>> =
                runs.iter().map(|r| r.first().copied()).collect();
            let mut lt = LoserTree::new(heads);
            let mut got = Vec::new();
            while let Some((leaf, key)) = lt.winner() {
                got.push(key);
                cursors[leaf] += 1;
                lt.replace(leaf, runs[leaf].get(cursors[leaf]).copied());
            }
            assert_eq!(got, want, "k={k}");
        }
    }

    #[test]
    fn loser_tree_handles_empty_and_exhausted() {
        let mut lt: LoserTree<u64> = LoserTree::new(Vec::new());
        assert!(lt.winner().is_none());
        lt = LoserTree::new(vec![Some(5)]);
        assert_eq!(lt.winner(), Some((0, 5)));
        lt.replace(0, None);
        assert!(lt.winner().is_none());
    }
}
