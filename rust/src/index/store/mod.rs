//! `SfcStore` — a sharded, **mutable**, concurrently-readable SFC store.
//!
//! The serving-layer composition of the query subsystem: points live in
//! curve-key-sorted segments ([`segment`]) stacked per shard in an
//! LSM-flavored hierarchy ([`shard`]: unsorted write buffer → sorted
//! runs in geometric size tiers; deletes are tombstones; `compact()`
//! does the full merge), the curve key space is split into contiguous
//! **curve-order shards** (equi-depth from the build sample,
//! rebalanceable), and every query is planned by [`planner`]: decompose
//! the window once, cut the ranges at the shard fenceposts, probe
//! exactly the shards the window intersects.
//!
//! ## Epoch/snapshot reads
//!
//! Readers never block on ingest: a query grabs an [`Arc<Snapshot>`]
//! (the published segment lists of every shard) and runs entirely on
//! immutable data — writers build new segment lists off to the side and
//! swap the published `Arc` under a briefly-held mutex. A snapshot taken
//! before a batch of inserts never sees them (snapshot isolation), and
//! compaction swaps merged segments in without disturbing in-flight
//! queries, which keep their old `Arc`s alive until they finish.
//!
//! ## Visibility
//!
//! Every mutation carries a global sequence number; an entry is visible
//! when it holds the **maximum sequence for its id** among the entries a
//! query's ranges reach, and that winner is not a tombstone. Inserts and
//! the tombstone that deletes them share a curve key (deletes pass the
//! inserted point), so a range that sees one always sees the other.
//! Results are exact for the same reason [`SfcIndex`] is: candidates
//! pass the shared float filter ([`quantize::window_contains`]) before
//! they are returned.

pub mod segment;
pub mod planner;
pub(crate) mod shard;

use crate::apps::Matrix;
use crate::curves::engine::{with_cells_scratch, CurveMapperNd, DomainNd};
use crate::curves::fastkey::KeyPath;
use crate::curves::CurveKind;
use crate::curves::neighbor::{NeighborFinder, NeighborPath};
use crate::index::knn::{expanding_knn, merge_ranges, subtract_ranges};
use crate::index::quantize::{clamped_level, window_contains, Quantizer};
use crate::index::QueryStats;
use planner::{plan_window, QueryPlan, ShardProbe};
use segment::Segment;
use shard::ShardState;
use std::collections::HashMap;
use std::ops::Range;
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

/// Tuning knobs of an [`SfcStore`].
#[derive(Copy, Clone, Debug)]
pub struct StoreConfig {
    /// Contiguous curve-order shards (each an independent segment
    /// stack). Default 8.
    pub shards: usize,
    /// Write-buffer row budget per shard before a flush. Default 256.
    pub buffer_rows: usize,
}

impl Default for StoreConfig {
    fn default() -> Self {
        StoreConfig { shards: 8, buffer_rows: 256 }
    }
}

/// An immutable read epoch: the published segment lists of every shard
/// plus the shard fenceposts they were routed under. Queries planned
/// against a snapshot see exactly the mutations sequenced before it —
/// never writes that landed after ([`SfcStore::snapshot`]).
#[derive(Clone, Debug)]
pub struct Snapshot {
    /// Shard fenceposts on the curve-order axis (`shards + 1` entries).
    bounds: Vec<u64>,
    /// Per-shard segment lists (runs then write-buffer mini-runs).
    shards: Vec<Arc<Vec<Arc<Segment>>>>,
    /// Running bounding box of every row ever written (inserts and
    /// tombstones; never shrinks — the kNN cover test needs a box that
    /// contains every live point).
    data_lo: Vec<f32>,
    data_hi: Vec<f32>,
    /// Total entries across all segments (tombstones included).
    entries: u64,
}

impl Snapshot {
    /// Total entries (tombstones and superseded versions included).
    pub fn entries(&self) -> u64 {
        self.entries
    }

    /// Shard fenceposts on the curve-order axis.
    pub fn bounds(&self) -> &[u64] {
        &self.bounds
    }

    /// Entries per shard (tombstones included).
    pub fn shard_entry_counts(&self) -> Vec<usize> {
        self.shards
            .iter()
            .map(|segs| segs.iter().map(|s| s.rows()).sum())
            .collect()
    }

    /// Segments per shard.
    pub fn shard_segment_counts(&self) -> Vec<usize> {
        self.shards.iter().map(|segs| segs.len()).collect()
    }

    /// One shard's published segment stack (runs then write-buffer
    /// mini-runs) — the byte-level parity tests compare these across
    /// the serial and parallel maintenance paths.
    pub fn shard_segments(&self, shard: usize) -> &[Arc<Segment>] {
        &self.shards[shard]
    }

    fn recount(&mut self) {
        self.entries = self
            .shards
            .iter()
            .flat_map(|segs| segs.iter())
            .map(|s| s.rows() as u64)
            .sum();
    }
}

/// A visible candidate during resolution: the winning entry for an id.
#[derive(Copy, Clone)]
struct Hit {
    seq: u64,
    tomb: bool,
    shard: u32,
    seg: u32,
    pos: u32,
}

/// Shard index owning `key` under the fenceposts `bounds`.
fn shard_of(bounds: &[u64], key: u64) -> usize {
    let slots = bounds.len() - 1;
    bounds[1..slots].partition_point(|&b| b <= key)
}

/// Sharded, mutable, concurrently-readable SFC store over `n×d` float
/// rows (see the [module docs](self) for the segment/shard/epoch
/// design).
pub struct SfcStore {
    kind: CurveKind,
    level: u32,
    dims: usize,
    quant: Quantizer,
    mapper: Box<dyn CurveMapperNd>,
    span: u64,
    buffer_rows: usize,
    /// Shard fenceposts; writers hold the read half across routing +
    /// append so a rebalance (write half) can never re-cut the key space
    /// under a half-routed batch.
    routing: RwLock<Vec<u64>>,
    /// Per-shard writer locks over the mutable segment stacks.
    shards: Vec<Mutex<ShardState>>,
    /// The published read epoch (see [`Snapshot`]).
    published: Mutex<Arc<Snapshot>>,
    next_seq: AtomicU64,
    next_id: AtomicU32,
}

impl SfcStore {
    /// Store over `dims`-column rows quantized to `2^level` cells per
    /// axis across the box `[origin, max]`, with equal-width shard
    /// fenceposts. Points outside the box clamp to the edge cells (the
    /// same conservative map queries use), so the store accepts any row.
    pub fn new(
        dims: usize,
        level: u32,
        kind: CurveKind,
        origin: Vec<f32>,
        max: &[f32],
        cfg: StoreConfig,
    ) -> Self {
        assert!(dims >= 1, "store needs at least one dimension");
        assert!(cfg.shards >= 1, "store needs at least one shard");
        let level = clamped_level(kind, dims, level);
        let mapper = kind.nd_mapper(dims, level);
        let side = match mapper.domain_nd() {
            DomainNd::HyperRect { shape } => shape[0],
            _ => unreachable!("nd_mapper domains are hyperrects"),
        };
        let span = mapper.order_span_nd().expect("nd_mapper spans are finite");
        let quant = Quantizer::from_bounds(origin, max, side);
        // Equal-width fenceposts (the empty-sample equi-depth fallback);
        // `from_points` replaces these with data-driven ones.
        let shards = cfg.shards.min(span.max(1) as usize);
        let bounds = equi_depth_bounds(&[], shards, span);
        let snapshot = Snapshot {
            bounds: bounds.clone(),
            shards: (0..shards).map(|_| Arc::new(Vec::new())).collect(),
            data_lo: vec![f32::INFINITY; dims],
            data_hi: vec![f32::NEG_INFINITY; dims],
            entries: 0,
        };
        SfcStore {
            kind,
            level,
            dims,
            quant,
            mapper,
            span,
            buffer_rows: cfg.buffer_rows.max(1),
            routing: RwLock::new(bounds),
            shards: (0..shards).map(|_| Mutex::new(ShardState::default())).collect(),
            published: Mutex::new(Arc::new(snapshot)),
            next_seq: AtomicU64::new(1),
            next_id: AtomicU32::new(0),
        }
    }

    /// Build a store from an initial point set: quantization bounds from
    /// the data, **equi-depth** shard fenceposts from the points' curve
    /// keys, then a bulk ingest (ids `0..rows`).
    pub fn from_points(points: &Matrix, level: u32, kind: CurveKind, cfg: StoreConfig) -> Self {
        let dims = points.cols;
        let (origin, max) = match crate::index::axis_bounds(points, dims.max(1)) {
            Some(b) => b,
            None => (vec![0.0; dims], vec![0.0; dims]),
        };
        let store = Self::new(dims, level, kind, origin, &max, cfg);
        if points.rows > 0 {
            // Equi-depth fenceposts from the full key sample, through the
            // block quantize + batched-key fast path.
            let mut keys = Vec::with_capacity(points.rows);
            with_cells_scratch(|flat| {
                store.quant.cells_block(points, flat);
                store.mapper.order_batch_nd(flat, &mut keys);
            });
            keys.sort_unstable();
            let bounds = equi_depth_bounds(&keys, store.shards.len(), store.span);
            *store.routing.write().expect("store lock poisoned") = bounds.clone();
            {
                let mut g = store.published.lock().expect("store lock poisoned");
                let mut snap = (**g).clone();
                snap.bounds = bounds;
                *g = Arc::new(snap);
            }
            store.insert_batch(points);
        }
        store
    }

    /// The curve the keys live on.
    pub fn curve(&self) -> CurveKind {
        self.kind
    }

    /// Quantization level actually used (clamped like
    /// [`SfcIndex`](crate::index::SfcIndex)).
    pub fn level(&self) -> u32 {
        self.level
    }

    /// Row dimensionality.
    pub fn dims(&self) -> usize {
        self.dims
    }

    /// Number of curve-order shards.
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// The store's quantizer (shared float→cell map).
    pub fn quantizer(&self) -> &Quantizer {
        &self.quant
    }

    /// Which key-conversion substrate ingest batches run on — fast-path
    /// introspection (see [`crate::curves::fastkey`]).
    pub fn key_path(&self) -> KeyPath {
        self.mapper.key_path_nd()
    }

    /// The d-dimensional curve mapper the keys live on — shared with
    /// callers that build neighbor stencils against the store's key
    /// space (the jump similarity join).
    pub fn mapper_nd(&self) -> &dyn CurveMapperNd {
        self.mapper.as_ref()
    }

    /// Which neighbor-stepping substrate stencil probes against this
    /// store walk cells with (see [`crate::curves::neighbor`]) —
    /// introspection mirroring [`SfcStore::key_path`].
    pub fn neighbor_path(&self) -> NeighborPath {
        NeighborFinder::new(self.mapper.as_ref()).path()
    }

    /// Which sort-engine path ([`crate::util::sort`]) a curve-order sort
    /// of the store's current entry count selects on this machine — the
    /// sort a rebuild or full compaction of today's data would run.
    /// Introspection mirroring [`SfcStore::key_path`] and
    /// [`SfcStore::neighbor_path`], so tests can assert the store never
    /// silently falls back to the comparison sort at scale.
    pub fn sort_path(&self) -> crate::util::sort::SortPath {
        let n = self.snapshot().entries() as usize;
        crate::util::sort::sort_path(n, crate::util::sort::default_threads())
    }

    // ------------------------------------------------------------------
    // Mutation
    // ------------------------------------------------------------------

    /// Insert one row, returning its assigned id.
    pub fn insert(&self, point: &[f32]) -> u32 {
        assert_eq!(point.len(), self.dims, "row dims must match the store");
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let m = Matrix { rows: 1, cols: self.dims, data: point.to_vec() };
        self.apply(vec![id], m, false);
        id
    }

    /// Insert a batch of rows; ids are assigned sequentially and the
    /// first one is returned.
    pub fn insert_batch(&self, rows: &Matrix) -> u32 {
        assert_eq!(rows.cols, self.dims, "row dims must match the store");
        let n = rows.rows as u32;
        let first = self.next_id.fetch_add(n, Ordering::Relaxed);
        if n == 0 {
            return first;
        }
        self.apply((first..first + n).collect(), rows.clone(), false);
        first
    }

    /// Delete the point `id` by writing a tombstone. `point` must be the
    /// row that was inserted under `id` — the tombstone takes its curve
    /// key from it, which is what guarantees any range probe that can
    /// see the insert also sees the delete.
    pub fn delete(&self, id: u32, point: &[f32]) {
        assert_eq!(point.len(), self.dims, "row dims must match the store");
        let m = Matrix { rows: 1, cols: self.dims, data: point.to_vec() };
        self.apply(vec![id], m, true);
    }

    /// Route a batch to shards and append per-shard mini-runs, then
    /// publish the new epoch.
    fn apply(&self, ids: Vec<u32>, points: Matrix, tomb: bool) {
        let n = points.rows;
        let seq0 = self.next_seq.fetch_add(n as u64, Ordering::Relaxed);
        // Hold routing (read) across the whole append so a concurrent
        // rebalance cannot re-cut the key space under this batch.
        let routing = self.routing.read().expect("store lock poisoned");
        let mut keys = Vec::with_capacity(n);
        with_cells_scratch(|flat| {
            self.quant.cells_block(&points, flat);
            self.mapper.order_batch_nd(flat, &mut keys);
        });
        // Partition rows by shard (preserving order, so per-shard seqs
        // stay ascending).
        let mut groups: HashMap<usize, (Vec<u32>, Matrix, Vec<u64>)> = HashMap::new();
        for p in 0..n {
            let s = shard_of(&routing, keys[p]);
            let g = groups
                .entry(s)
                .or_insert_with(|| (Vec::new(), Matrix::zeros(0, self.dims), Vec::new()));
            g.0.push(ids[p]);
            g.1.data.extend_from_slice(points.row(p));
            g.1.rows += 1;
            g.2.push(seq0 + p as u64);
        }
        let mut touched: Vec<usize> = groups.keys().copied().collect();
        touched.sort_unstable();
        for s in touched {
            let (gids, grows, gseqs) = groups.remove(&s).expect("key from keys()");
            let mut seg =
                Segment::from_rows(self.mapper.as_ref(), &self.quant, gids, grows, tomb, 0);
            seg.seqs = gseqs;
            // Publish while the shard writer lock is still held (lock
            // order shard → published, same as rebalance): releasing it
            // first would let a faster sibling writer publish a newer
            // list that this one then clobbers with a stale epoch.
            let mut state = self.shards[s].lock().expect("store lock poisoned");
            state.append(seg, self.buffer_rows, self.dims);
            self.publish_shard(s, state.segments(), Some(&points));
        }
    }

    /// Swap shard `s`'s segment list into the published epoch (and grow
    /// the data bounding box by `batch`, if any). The entry count
    /// updates by delta — only the replaced shard's segments are
    /// walked, not the whole store.
    fn publish_shard(&self, s: usize, segs: Vec<Arc<Segment>>, batch: Option<&Matrix>) {
        let mut g = self.published.lock().expect("store lock poisoned");
        let mut snap = (**g).clone();
        let old: u64 = snap.shards[s].iter().map(|seg| seg.rows() as u64).sum();
        let new: u64 = segs.iter().map(|seg| seg.rows() as u64).sum();
        snap.shards[s] = Arc::new(segs);
        snap.entries = snap.entries - old + new;
        if let Some(batch) = batch {
            for p in 0..batch.rows {
                for (a, &v) in batch.row(p).iter().enumerate() {
                    snap.data_lo[a] = snap.data_lo[a].min(v);
                    snap.data_hi[a] = snap.data_hi[a].max(v);
                }
            }
        }
        *g = Arc::new(snap);
    }

    /// Flush every shard's write buffer into sorted runs.
    pub fn flush(&self) {
        let _routing = self.routing.read().expect("store lock poisoned");
        for s in 0..self.shards.len() {
            let mut state = self.shards[s].lock().expect("store lock poisoned");
            state.flush(self.dims);
            self.publish_shard(s, state.segments(), None);
        }
    }

    /// Fully compact every shard: one sorted, tombstone-free run each.
    /// In-flight queries keep their pre-compaction snapshots alive and
    /// are unaffected.
    pub fn compact(&self) {
        let _routing = self.routing.read().expect("store lock poisoned");
        for s in 0..self.shards.len() {
            let mut state = self.shards[s].lock().expect("store lock poisoned");
            state.compact(self.dims);
            self.publish_shard(s, state.segments(), None);
        }
    }

    /// Re-cut the shard fenceposts **equi-depth** over the live keys and
    /// redistribute every entry. Exclusive with writers (takes the
    /// routing write lock); readers keep their old snapshots.
    pub fn rebalance(&self) {
        let mut routing = self.routing.write().expect("store lock poisoned");
        let mut guards: Vec<_> = self
            .shards
            .iter()
            .map(|s| s.lock().expect("store lock poisoned"))
            .collect();
        // Full-merge everything into one resolved, tombstone-free run.
        let all: Vec<Arc<Segment>> = guards.iter().flat_map(|g| g.segments()).collect();
        let refs: Vec<&Segment> = all.iter().map(|s| s.as_ref()).collect();
        let merged = Segment::merge(&refs, true, self.dims);
        // Cut the merged run at the new fenceposts.
        let bounds = equi_depth_bounds(&merged.keys, self.shards.len(), self.span);
        let cuts = cut_positions(&merged.keys, &bounds);
        let per_shard: Vec<Vec<Arc<Segment>>> = (0..self.shards.len())
            .map(|s| cut_slice(&merged, cuts[s], cuts[s + 1], self.dims))
            .collect();
        self.install_rebalanced(&mut routing, &mut guards, bounds, per_shard);
    }

    /// Swap the rebalanced per-shard runs, fenceposts, and published
    /// epoch in — the shared tail of [`SfcStore::rebalance`] and
    /// [`SfcStore::par_rebalance`], so both paths install byte-identical
    /// state.
    fn install_rebalanced(
        &self,
        routing: &mut Vec<u64>,
        guards: &mut [std::sync::MutexGuard<'_, ShardState>],
        bounds: Vec<u64>,
        per_shard: Vec<Vec<Arc<Segment>>>,
    ) {
        for (g, segs) in guards.iter_mut().zip(&per_shard) {
            g.minis.clear();
            g.mini_rows = 0;
            g.runs = segs.clone();
        }
        *routing = bounds.clone();
        let mut g = self.published.lock().expect("store lock poisoned");
        let mut snap = (**g).clone();
        snap.bounds = bounds;
        snap.shards = per_shard.into_iter().map(Arc::new).collect();
        snap.recount();
        *g = Arc::new(snap);
    }

    // ------------------------------------------------------------------
    // Parallel maintenance
    // ------------------------------------------------------------------

    /// [`SfcStore::flush`] with the per-shard work fanned across the
    /// coordinator's workers. Shards are independent under the lock
    /// discipline — each worker holds exactly one shard's writer lock,
    /// and the published-epoch mutex is only taken while holding it
    /// (the same shard → published order every writer uses) — so any
    /// thread count converges to exactly the serial path's state.
    pub fn par_flush(&self, coord: &crate::coordinator::Coordinator) {
        let _routing = self.routing.read().expect("store lock poisoned");
        let shards: Vec<usize> = (0..self.shards.len()).collect();
        coord.par_map(&shards, |_, &s| {
            let mut state = self.shards[s].lock().expect("store lock poisoned");
            state.flush(self.dims);
            self.publish_shard(s, state.segments(), None);
        });
    }

    /// [`SfcStore::compact`] with the per-shard full merges fanned
    /// across the coordinator's workers (same lock discipline as
    /// [`SfcStore::par_flush`]; converges to the serial result for any
    /// thread count). In-flight queries keep their pre-compaction
    /// snapshots alive and are unaffected.
    pub fn par_compact(&self, coord: &crate::coordinator::Coordinator) {
        let _routing = self.routing.read().expect("store lock poisoned");
        let shards: Vec<usize> = (0..self.shards.len()).collect();
        coord.par_map(&shards, |_, &s| {
            let mut state = self.shards[s].lock().expect("store lock poisoned");
            state.compact(self.dims);
            self.publish_shard(s, state.segments(), None);
        });
    }

    /// [`SfcStore::rebalance`] with the merge fanned across the
    /// coordinator's workers: stage 1 full-merges each shard's stack in
    /// parallel with tombstones **kept** (an entry an old shard holds
    /// may be cancelled by a tombstone routed to a different shard
    /// after an earlier rebalance moved the fenceposts), stage 2
    /// cross-shard-resolves the per-shard runs and drops tombstones,
    /// and the fencepost cuts copy out in parallel. Staged merging is
    /// exact: the global max-seq winner per id survives stage 1 in its
    /// shard, and both stages emit the same total `(key, seq, id)`
    /// order, so the result is **byte-identical** to the serial
    /// all-at-once merge for any thread count.
    pub fn par_rebalance(&self, coord: &crate::coordinator::Coordinator) {
        let mut routing = self.routing.write().expect("store lock poisoned");
        let mut guards: Vec<_> = self
            .shards
            .iter()
            .map(|s| s.lock().expect("store lock poisoned"))
            .collect();
        let stacks: Vec<Vec<Arc<Segment>>> = guards.iter().map(|g| g.segments()).collect();
        let shard_runs: Vec<Segment> = coord.par_map(&stacks, |_, stack| {
            let refs: Vec<&Segment> = stack.iter().map(|s| s.as_ref()).collect();
            Segment::merge(&refs, false, self.dims)
        });
        let refs: Vec<&Segment> = shard_runs.iter().collect();
        let merged = Segment::merge(&refs, true, self.dims);
        let bounds = equi_depth_bounds(&merged.keys, self.shards.len(), self.span);
        let cuts = cut_positions(&merged.keys, &bounds);
        let shard_ids: Vec<usize> = (0..self.shards.len()).collect();
        let per_shard: Vec<Vec<Arc<Segment>>> =
            coord.par_map(&shard_ids, |_, &s| cut_slice(&merged, cuts[s], cuts[s + 1], self.dims));
        self.install_rebalanced(&mut routing, &mut guards, bounds, per_shard);
    }

    // ------------------------------------------------------------------
    // Reads
    // ------------------------------------------------------------------

    /// The current read epoch. All `*_on` queries against it see exactly
    /// the state at this call — later mutations are invisible.
    pub fn snapshot(&self) -> Arc<Snapshot> {
        Arc::clone(&self.published.lock().expect("store lock poisoned"))
    }

    /// Live point count (resolves visibility; `O(entries)`).
    pub fn len(&self) -> usize {
        self.collect_live(&self.snapshot()).0.len()
    }

    /// True when no live points exist.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Plan a window query against a snapshot (decompose once, coarsen,
    /// route to shards).
    pub fn plan_window(
        &self,
        snap: &Snapshot,
        lo: &[f32],
        hi: &[f32],
        max_ranges: usize,
    ) -> QueryPlan {
        plan_window(self.mapper.as_ref(), &self.quant, &snap.bounds, lo, hi, max_ranges)
    }

    /// Probe one shard's segment stack, resolving per-id winners within
    /// the shard. Returns `(winners, candidates, segments_probed,
    /// key_probes)` — one key probe per range on each sorted segment,
    /// one per unsorted mini-run (those are scanned, not searched).
    fn probe_shard(snap: &Snapshot, probe: &ShardProbe) -> (Vec<(u32, Hit)>, u64, usize, u64) {
        let segs = &snap.shards[probe.shard];
        let mut best: HashMap<u32, Hit> = HashMap::new();
        let mut candidates = 0u64;
        let mut key_probes = 0u64;
        for (si, seg) in segs.iter().enumerate() {
            key_probes += if seg.sorted { probe.ranges.len() as u64 } else { 1 };
            seg.probe_ranges(&probe.ranges, |pos| {
                candidates += 1;
                let hit = Hit {
                    seq: seg.seqs[pos],
                    tomb: seg.tombs[pos],
                    shard: probe.shard as u32,
                    seg: si as u32,
                    pos: pos as u32,
                };
                best.entry(seg.ids[pos])
                    .and_modify(|b| {
                        if hit.seq > b.seq {
                            *b = hit;
                        }
                    })
                    .or_insert(hit);
            });
        }
        (best.into_iter().collect(), candidates, segs.len(), key_probes)
    }

    /// Merge per-shard winners (max seq per id across shards), drop
    /// tombstoned ids, and return the survivors sorted in curve order
    /// (shard, key, id).
    fn resolve(snap: &Snapshot, shard_hits: Vec<Vec<(u32, Hit)>>) -> Vec<(u32, Hit)> {
        let mut best: HashMap<u32, Hit> = HashMap::new();
        for hits in shard_hits {
            for (id, hit) in hits {
                best.entry(id)
                    .and_modify(|b| {
                        if hit.seq > b.seq {
                            *b = hit;
                        }
                    })
                    .or_insert(hit);
            }
        }
        let mut live: Vec<(u32, Hit)> = best.into_iter().filter(|(_, h)| !h.tomb).collect();
        live.sort_unstable_by_key(|&(id, h)| {
            let seg = &snap.shards[h.shard as usize][h.seg as usize];
            (h.shard, seg.keys[h.pos as usize], id)
        });
        live
    }

    /// Shared tail of every window plan execution: fold the per-shard
    /// probe outputs into the stats, resolve visibility across shards,
    /// and exact-filter the winners. Returns live ids in curve order.
    fn finish_plan(
        snap: &Snapshot,
        plan: &QueryPlan,
        shard_hits: Vec<(Vec<(u32, Hit)>, u64, usize, u64)>,
        stats: &mut QueryStats,
        mut filter: impl FnMut(u32, &[f32]) -> bool,
    ) -> Vec<u32> {
        // Accumulating (not assigning) lets the kNN radius schedule fold
        // several plan executions into one stats record.
        stats.ranges += plan.ranges.len();
        stats.shards_touched += plan.probes.len();
        let mut hits = Vec::with_capacity(shard_hits.len());
        for (h, cands, segs, probes) in shard_hits {
            stats.candidates += cands;
            stats.segments_probed += segs;
            stats.key_probes += probes;
            hits.push(h);
        }
        let mut out = Vec::new();
        for (id, h) in Self::resolve(snap, hits) {
            let seg = &snap.shards[h.shard as usize][h.seg as usize];
            if filter(id, seg.row(h.pos as usize)) {
                out.push(id);
                stats.results += 1;
            }
        }
        out
    }

    /// Execute a plan against a snapshot serially: probe each shard,
    /// then [`SfcStore::finish_plan`].
    fn run_plan(
        snap: &Snapshot,
        plan: &QueryPlan,
        stats: &mut QueryStats,
        filter: impl FnMut(u32, &[f32]) -> bool,
    ) -> Vec<u32> {
        let shard_hits = plan.probes.iter().map(|p| Self::probe_shard(snap, p)).collect();
        Self::finish_plan(snap, plan, shard_hits, stats, filter)
    }

    /// Ids of all live points inside the closed float window `[lo, hi]`
    /// on the given snapshot.
    pub fn query_window_on(&self, snap: &Snapshot, lo: &[f32], hi: &[f32]) -> Vec<u32> {
        self.query_window_stats_on(snap, lo, hi, 0).0
    }

    /// [`SfcStore::query_window_on`] with statistics and a `max_ranges`
    /// coarsening cap (`0` = exact decomposition).
    pub fn query_window_stats_on(
        &self,
        snap: &Snapshot,
        lo: &[f32],
        hi: &[f32],
        max_ranges: usize,
    ) -> (Vec<u32>, QueryStats) {
        let mut stats = QueryStats::default();
        let plan = self.plan_window(snap, lo, hi, max_ranges);
        let out = Self::run_plan(snap, &plan, &mut stats, |_, row| window_contains(lo, hi, row));
        (out, stats)
    }

    /// Window query on the current epoch.
    pub fn query_window(&self, lo: &[f32], hi: &[f32]) -> Vec<u32> {
        self.query_window_on(&self.snapshot(), lo, hi)
    }

    /// [`SfcStore::query_window`] with statistics.
    pub fn query_window_stats(
        &self,
        lo: &[f32],
        hi: &[f32],
        max_ranges: usize,
    ) -> (Vec<u32>, QueryStats) {
        self.query_window_stats_on(&self.snapshot(), lo, hi, max_ranges)
    }

    /// All live points exactly equal to `q` on the given snapshot (one
    /// key lookup plus the shared equality filter).
    pub fn query_point_on(&self, snap: &Snapshot, q: &[f32]) -> Vec<u32> {
        assert_eq!(q.len(), self.dims, "query dims must match the store");
        let key = self.quant.key_of(self.mapper.as_ref(), q);
        let plan = planner::plan_ranges(vec![key..key + 1], &snap.bounds);
        let mut stats = QueryStats::default();
        Self::run_plan(snap, &plan, &mut stats, |_, row| row == q)
    }

    /// Point query on the current epoch.
    pub fn query_point(&self, q: &[f32]) -> Vec<u32> {
        self.query_point_on(&self.snapshot(), q)
    }

    /// Live ids of the points whose cells are exactly the given
    /// **sorted, unique** curve keys — the store's key-jump probe. No
    /// window, no decomposition, no float filter: the keys (typically a
    /// neighbor stencil from
    /// [`NeighborFinder`](crate::curves::neighbor::NeighborFinder))
    /// merge into unit-cell runs, route across the shard fenceposts
    /// ([`planner::plan_keys`]) and resolve visibility like any window
    /// probe. Callers apply their own exact predicate to the survivors.
    /// Visibility is exact per key because an insert and its tombstone
    /// share a curve key, so one key run sees every version of an id.
    pub fn query_keys_on(&self, snap: &Snapshot, keys: &[u64], stats: &mut QueryStats) -> Vec<u32> {
        if keys.is_empty() {
            return Vec::new();
        }
        let plan = planner::plan_keys(keys, &snap.bounds);
        Self::run_plan(snap, &plan, stats, |_, _| true)
    }

    /// The `k` nearest live neighbors of `q` by Euclidean distance,
    /// sorted ascending as `(id, distance)` — the shared
    /// expanding-window search over snapshot window queries.
    pub fn query_knn_on(&self, snap: &Snapshot, q: &[f32], k: usize) -> Vec<(u32, f32)> {
        self.query_knn_stats_on(snap, q, k).0
    }

    /// [`SfcStore::query_knn_on`] with query statistics. Expansion
    /// shells probe only their *delta*: key ranges covered by earlier,
    /// smaller windows are subtracted before planning, so no range is
    /// decomposed into probes twice across the radius schedule.
    /// Candidates from covered cells skip the float filter — the shared
    /// driver dedups by id and far points never displace true
    /// neighbors — which is also what makes delta probing exact: a
    /// covered point outside an early float window is already in the
    /// driver's heap when the window grows over it.
    pub fn query_knn_stats_on(
        &self,
        snap: &Snapshot,
        q: &[f32],
        k: usize,
    ) -> (Vec<(u32, f32)>, QueryStats) {
        assert_eq!(q.len(), self.dims, "query dims must match the store");
        let mut stats = QueryStats::default();
        if snap.entries == 0 || k == 0 {
            return (Vec::new(), stats);
        }
        let mut covered: Vec<Range<u64>> = Vec::new();
        let out = expanding_knn(
            q,
            k,
            self.quant.max_cell_width(),
            &snap.data_lo,
            &snap.data_hi,
            |lo, hi, emit| {
                let ranges = self.mapper.decompose_nd(&self.quant.window(lo, hi));
                let delta = subtract_ranges(&ranges, &covered);
                let plan = planner::plan_ranges(delta.clone(), &snap.bounds);
                Self::run_plan(snap, &plan, &mut stats, |id, row| {
                    emit(id, row);
                    false
                });
                merge_ranges(&mut covered, &delta);
            },
        );
        stats.results = out.len() as u64;
        (out, stats)
    }

    /// kNN query on the current epoch.
    pub fn query_knn(&self, q: &[f32], k: usize) -> Vec<(u32, f32)> {
        self.query_knn_on(&self.snapshot(), q, k)
    }

    /// Window query with the **per-shard probes fanned across the
    /// coordinator's workers** ([`Coordinator::par_map`] over the plan's
    /// probe list): each worker binary-searches one shard's segment
    /// stack, and the per-shard winners merge on the calling thread —
    /// the serving path for large windows on many-shard stores.
    pub fn par_query_window(
        &self,
        coord: &crate::coordinator::Coordinator,
        lo: &[f32],
        hi: &[f32],
        max_ranges: usize,
    ) -> (Vec<u32>, QueryStats) {
        let snap = self.snapshot();
        let mut stats = QueryStats::default();
        let plan = self.plan_window(&snap, lo, hi, max_ranges);
        let shard_hits = coord.par_map(&plan.probes, |_, probe| Self::probe_shard(&snap, probe));
        let out = Self::finish_plan(&snap, &plan, shard_hits, &mut stats, |_, row| {
            window_contains(lo, hi, row)
        });
        (out, stats)
    }

    /// Materialize the live point set of a snapshot in **curve order**:
    /// `(ids, rows)` with `rows.row(i)` the point of `ids[i]`. This is
    /// the store's full-scan face — the streaming k-means refinement
    /// feeds its coordinator shards from it, and the parity tests
    /// rebuild a fresh [`SfcIndex`](crate::index::SfcIndex) over it.
    pub fn collect_live(&self, snap: &Snapshot) -> (Vec<u32>, Matrix) {
        let mut best: HashMap<u32, Hit> = HashMap::new();
        for (s, segs) in snap.shards.iter().enumerate() {
            for (si, seg) in segs.iter().enumerate() {
                for pos in 0..seg.rows() {
                    let hit = Hit {
                        seq: seg.seqs[pos],
                        tomb: seg.tombs[pos],
                        shard: s as u32,
                        seg: si as u32,
                        pos: pos as u32,
                    };
                    best.entry(seg.ids[pos])
                        .and_modify(|b| {
                            if hit.seq > b.seq {
                                *b = hit;
                            }
                        })
                        .or_insert(hit);
                }
            }
        }
        let mut live: Vec<(u64, u32, Hit)> = best
            .into_iter()
            .filter(|(_, h)| !h.tomb)
            .map(|(id, h)| {
                let seg = &snap.shards[h.shard as usize][h.seg as usize];
                (seg.keys[h.pos as usize], id, h)
            })
            .collect();
        // (key, id) is the curve order; the shard index is implied by
        // the key, so a global key sort crosses shards correctly.
        live.sort_unstable_by_key(|&(key, id, _)| (key, id));
        let mut ids = Vec::with_capacity(live.len());
        let mut rows = Matrix::zeros(0, self.dims);
        for (_, id, h) in live {
            ids.push(id);
            let seg = &snap.shards[h.shard as usize][h.seg as usize];
            rows.data.extend_from_slice(seg.row(h.pos as usize));
            rows.rows += 1;
        }
        (ids, rows)
    }
}

/// Absolute positions where the fenceposts cut a sorted key column:
/// `bounds.len()` entries, `cuts[s]..cuts[s + 1]` = shard `s`'s slice.
fn cut_positions(sorted_keys: &[u64], bounds: &[u64]) -> Vec<usize> {
    let mut cuts = Vec::with_capacity(bounds.len());
    cuts.push(0);
    for &b in &bounds[1..] {
        cuts.push(sorted_keys.partition_point(|&k| k < b));
    }
    cuts
}

/// One shard's post-rebalance segment list: the merged run's
/// `[start, end)` slice as a single sorted run (empty slice → empty
/// stack).
fn cut_slice(merged: &Segment, start: usize, end: usize, dims: usize) -> Vec<Arc<Segment>> {
    if end <= start {
        return Vec::new();
    }
    vec![Arc::new(Segment {
        keys: merged.keys[start..end].to_vec(),
        ids: merged.ids[start..end].to_vec(),
        seqs: merged.seqs[start..end].to_vec(),
        tombs: merged.tombs[start..end].to_vec(),
        points: Matrix {
            rows: end - start,
            cols: dims,
            data: merged.points.data[start * dims..end * dims].to_vec(),
        },
        sorted: true,
    })]
}

/// Equi-depth fenceposts over a **sorted** key sample: `shards + 1`
/// non-decreasing bounds from 0 to `span`, cutting the sample into
/// near-equal slices (empty shards are legal when keys repeat).
fn equi_depth_bounds(sorted_keys: &[u64], shards: usize, span: u64) -> Vec<u64> {
    if sorted_keys.is_empty() {
        // Nothing to sample: fall back to equal-width fenceposts.
        let s = shards as u64;
        return (0..=s).map(|j| j * (span / s) + j.min(span % s)).collect();
    }
    let mut bounds = Vec::with_capacity(shards + 1);
    bounds.push(0);
    for j in 1..shards {
        let q = sorted_keys[(j * sorted_keys.len()) / shards];
        bounds.push(q.max(*bounds.last().expect("non-empty")));
    }
    bounds.push(span);
    // Fenceposts must not exceed span (keys are < span by construction,
    // but stay defensive).
    for b in bounds.iter_mut() {
        *b = (*b).min(span);
    }
    bounds
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::simjoin::make_clustered;

    #[test]
    fn equi_depth_bounds_are_monotone_and_cover() {
        let keys: Vec<u64> = (0..100).map(|i| i * i % 4096).collect();
        let mut sorted = keys.clone();
        sorted.sort_unstable();
        let b = equi_depth_bounds(&sorted, 8, 4096);
        assert_eq!(b.len(), 9);
        assert_eq!(b[0], 0);
        assert_eq!(b[8], 4096);
        assert!(b.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn insert_query_roundtrip_with_sharding() {
        let points = make_clustered(500, 2, 10, 1.0, 3);
        let store = SfcStore::from_points(&points, 6, CurveKind::Hilbert, StoreConfig::default());
        assert_eq!(store.len(), 500);
        // Every point findable by exact lookup under its assigned id
        // (ids are 0..n in insert order).
        for p in [0usize, 123, 499] {
            let got = store.query_point(points.row(p));
            assert!(got.contains(&(p as u32)), "row {p}");
        }
    }

    #[test]
    fn delete_then_compact_removes_rows() {
        let points = make_clustered(200, 3, 5, 0.8, 9);
        let store = SfcStore::from_points(&points, 5, CurveKind::Hilbert, StoreConfig::default());
        for p in 0..100usize {
            store.delete(p as u32, points.row(p));
        }
        assert_eq!(store.len(), 100);
        let before: u64 = store.snapshot().entries();
        store.compact();
        let after = store.snapshot().entries();
        assert!(after < before, "compaction must shrink entries ({before} -> {after})");
        assert_eq!(store.len(), 100);
        for p in 0..100usize {
            assert!(store.query_point(points.row(p)).iter().all(|&id| id != p as u32));
        }
    }

    #[test]
    fn rebalance_preserves_the_live_set() {
        let points = make_clustered(400, 2, 40, 2.0, 21);
        let store = SfcStore::from_points(
            &points,
            6,
            CurveKind::Hilbert,
            StoreConfig { shards: 4, buffer_rows: 64 },
        );
        for p in 0..50usize {
            store.delete(p as u32, points.row(p));
        }
        let (ids_before, rows_before) = store.collect_live(&store.snapshot());
        assert_eq!(ids_before.len(), 350);
        store.rebalance();
        let (ids_after, rows_after) = store.collect_live(&store.snapshot());
        assert_eq!(ids_before, ids_after);
        assert_eq!(rows_before.data, rows_after.data);
        // After rebalancing no tombstones remain and no shard hoards
        // more than half the entries (equi-depth, up to key ties).
        let snap = store.snapshot();
        assert_eq!(snap.entries(), 350);
        let depths = snap.shard_entry_counts();
        assert!(*depths.iter().max().unwrap() <= 175, "equi-depth shards, got {depths:?}");
    }

    #[test]
    fn snapshot_does_not_see_later_writes() {
        let store = SfcStore::new(
            2,
            5,
            CurveKind::Hilbert,
            vec![0.0, 0.0],
            &[10.0, 10.0],
            StoreConfig::default(),
        );
        store.insert(&[1.0, 1.0]);
        let snap = store.snapshot();
        let id2 = store.insert(&[2.0, 2.0]);
        assert_eq!(store.query_window(&[0.0, 0.0], &[5.0, 5.0]).len(), 2);
        let old = store.query_window_on(&snap, &[0.0, 0.0], &[5.0, 5.0]);
        assert_eq!(old.len(), 1, "snapshot must not see the later insert");
        assert!(!old.contains(&id2));
    }
}
