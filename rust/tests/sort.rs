//! Sort/merge-engine test suite (ISSUE 8): duplicate-key (tie)
//! stability through every public consumer of the engine —
//! `sfc_argsort`, `SfcIndex::build`, `Segment::merge` — for every
//! `CurveKind` at d ∈ {2, 3, 4}; `SortPath` introspection asserting no
//! silent fallback to the comparison sort; and serial-vs-parallel store
//! maintenance parity, byte for byte, at every tested thread count.

use sfc_mine::apps::Matrix;
use sfc_mine::coordinator::Coordinator;
use sfc_mine::curves::engine::CurveMapperNd;
use sfc_mine::curves::ndim::sfc_argsort;
use sfc_mine::curves::CurveKind;
use sfc_mine::index::quantize::Quantizer;
use sfc_mine::index::store::segment::Segment;
use sfc_mine::index::{SfcIndex, SfcStore, Snapshot, StoreConfig};
use sfc_mine::util::rng::Rng;
use sfc_mine::util::sort::{
    comparison_argsort, default_threads, radix_argsort, sample_argsort, sort_path, SortPath,
    PAR_MIN_KEYS, RADIX_MIN_KEYS,
};

/// The engine's contract, checked at the `sfc_argsort` entry point every
/// index build and store flush goes through: bit-for-bit equal to the
/// stable comparison argsort — ties keep input order — on duplicate-heavy
/// coordinates for every curve × d ∈ {2, 3, 4}, at sizes selecting each
/// `SortPath`.
#[test]
fn sfc_argsort_keeps_input_order_on_ties_for_every_curve() {
    let mut rng = Rng::new(7);
    for kind in CurveKind::ALL {
        for d in [2usize, 3, 4] {
            let mapper = kind.nd_mapper(d, 4);
            for n in [RADIX_MIN_KEYS / 2, 3000] {
                // Coordinates from a tiny palette: almost every key ties.
                let flat: Vec<u32> = (0..n * d).map(|_| rng.below(4) as u32).collect();
                let mut keys = Vec::with_capacity(n);
                mapper.order_batch_nd(&flat, &mut keys);
                assert_eq!(
                    sfc_argsort(&flat, mapper.as_ref()),
                    comparison_argsort(&keys),
                    "{} d={d} n={n}: tie order must equal input order",
                    kind.name()
                );
            }
        }
    }
}

/// Radix and sample-sort agree with the comparison argsort — ties
/// included — above the parallel cutover, for every thread count.
#[test]
fn engine_paths_agree_above_parallel_cutover() {
    let mut rng = Rng::new(13);
    let n = PAR_MIN_KEYS + 123;
    let keys: Vec<u64> = (0..n).map(|_| rng.below(32)).collect(); // heavy ties
    let want = comparison_argsort(&keys);
    assert_eq!(radix_argsort(&keys), want, "radix tie order");
    for threads in [1usize, 2, 5, 8] {
        let coord = Coordinator::new(threads);
        assert_eq!(sample_argsort(&keys, &coord), want, "sample-sort at {threads} threads");
        assert_eq!(coord.par_argsort(&keys), want, "par_argsort at {threads} threads");
    }
}

/// `SortPath` selection plus the index/store introspection hooks: big
/// workloads never silently fall back to the comparison sort.
#[test]
fn sort_path_hooks_report_no_silent_fallback() {
    assert_eq!(sort_path(RADIX_MIN_KEYS - 1, 8), SortPath::Comparison);
    assert_eq!(sort_path(RADIX_MIN_KEYS, 1), SortPath::RadixLsd);
    assert_eq!(sort_path(PAR_MIN_KEYS, 1), SortPath::RadixLsd);
    assert_eq!(sort_path(PAR_MIN_KEYS, 2), SortPath::SampleSort);
    assert!(!SortPath::Comparison.is_fast());
    assert!(SortPath::RadixLsd.is_fast() && SortPath::SampleSort.is_fast());
    assert_eq!(SortPath::RadixLsd.name(), "radix-lsd");

    let points = Matrix::random(5000, 3, 3, 0.0, 50.0);
    let index = SfcIndex::build(&points, 6);
    assert_eq!(index.sort_path(), sort_path(index.len(), default_threads()));
    assert!(index.sort_path().is_fast(), "a 5000-row build must take a fast path");

    let store = SfcStore::from_points(&points, 6, CurveKind::Hilbert, StoreConfig::default());
    assert_eq!(
        store.sort_path(),
        sort_path(store.snapshot().entries() as usize, default_threads())
    );
    assert!(store.sort_path().is_fast(), "a 5000-entry store must take a fast path");
}

/// Duplicate rows through a real `SfcIndex::build`: equal keys keep
/// input order, so the ids a point query returns are exactly the
/// duplicate positions in insertion order.
#[test]
fn index_build_keeps_duplicate_rows_in_input_order() {
    let mut rng = Rng::new(29);
    for kind in CurveKind::ALL {
        for d in [2usize, 3, 4] {
            // 300 rows drawn from 20 distinct points: every row has many
            // exact duplicates (equal curve keys).
            let palette = Matrix::random(20, d, 31, 0.0, 10.0);
            let picks: Vec<usize> = (0..300).map(|_| rng.below_usize(20)).collect();
            let points = Matrix::from_fn(300, d, |i, j| palette.at(picks[i], j));
            let index = SfcIndex::build_with(&points, 5, kind);
            for p in 0..20 {
                let q = palette.row(p);
                let got = index.query_point(q);
                let want: Vec<u32> = picks
                    .iter()
                    .enumerate()
                    .filter(|&(_, &v)| v == p)
                    .map(|(i, _)| i as u32)
                    .collect();
                assert_eq!(got, want, "{} d={d}: duplicates out of input order", kind.name());
            }
        }
    }
}

/// `Segment::merge` on a duplicate-key mini-run: within equal keys the
/// output is in seq (append) order, for every curve × d ∈ {2, 3, 4}.
#[test]
fn merge_keeps_seq_order_on_equal_keys() {
    let mut rng = Rng::new(37);
    for kind in CurveKind::ALL {
        for d in [2usize, 3, 4] {
            let mapper = kind.nd_mapper(d, 4);
            let quant = Quantizer::from_bounds(vec![0.0; d], &vec![16.0; d], 16);
            // 80 rows over 5 distinct points → long equal-key runs.
            let palette: Vec<Vec<f32>> =
                (0..5).map(|_| (0..d).map(|_| rng.below(16) as f32).collect()).collect();
            let mut rows = Matrix::zeros(0, d);
            for _ in 0..80 {
                rows.data.extend_from_slice(&palette[rng.below_usize(5)]);
                rows.rows += 1;
            }
            let ids: Vec<u32> = (0..80).collect();
            let seg = Segment::from_rows(mapper.as_ref(), &quant, ids, rows, false, 1);
            let merged = Segment::merge(&[&seg], false, d);
            assert_eq!(merged.rows(), 80);
            assert!(merged.keys.windows(2).all(|w| w[0] <= w[1]), "sorted by key");
            for p in 1..merged.rows() {
                if merged.keys[p - 1] == merged.keys[p] {
                    assert!(
                        merged.seqs[p - 1] < merged.seqs[p],
                        "{} d={d}: equal keys must stay in seq order",
                        kind.name()
                    );
                }
            }
        }
    }
}

fn assert_seg_eq(a: &Segment, b: &Segment, ctx: &str) {
    assert_eq!(a.keys, b.keys, "{ctx}: keys");
    assert_eq!(a.ids, b.ids, "{ctx}: ids");
    assert_eq!(a.seqs, b.seqs, "{ctx}: seqs");
    assert_eq!(a.tombs, b.tombs, "{ctx}: tombs");
    assert_eq!(a.points.data, b.points.data, "{ctx}: row data");
}

fn assert_snap_eq(a: &Snapshot, b: &Snapshot, ctx: &str) {
    assert_eq!(a.bounds(), b.bounds(), "{ctx}: fenceposts");
    assert_eq!(a.entries(), b.entries(), "{ctx}: entries");
    let shards = a.bounds().len() - 1;
    for s in 0..shards {
        let (sa, sb) = (a.shard_segments(s), b.shard_segments(s));
        assert_eq!(sa.len(), sb.len(), "{ctx}: shard {s} segment count");
        for (x, y) in sa.iter().zip(sb) {
            assert_seg_eq(x, y, &format!("{ctx}: shard {s}"));
        }
    }
}

/// One deterministic mutation round: a batch of inserts plus deletes of
/// the round's own first rows (the same script for every store).
fn mutate(store: &SfcStore, round: u64) {
    let mut rng = Rng::new(1000 + round);
    let n = 40 + rng.below(40) as usize;
    let rows = Matrix::from_fn(n, 2, |_, _| rng.f32() * 100.0);
    let first = store.insert_batch(&rows);
    for i in 0..n / 4 {
        store.delete(first + i as u32, rows.row(i));
    }
}

/// The parallel maintenance acceptance: `par_flush` / `par_compact` /
/// `par_rebalance` leave the store **byte-identical** (fenceposts,
/// per-shard segment stacks, every column) to the serial paths, for any
/// thread count.
#[test]
fn parallel_maintenance_matches_serial_bit_for_bit() {
    for threads in [1usize, 2, 5, 8] {
        let coord = Coordinator::new(threads);
        let mk = || {
            SfcStore::new(
                2,
                6,
                CurveKind::Hilbert,
                vec![0.0, 0.0],
                &[100.0, 100.0],
                StoreConfig { shards: 4, buffer_rows: 32 },
            )
        };
        let (serial, par) = (mk(), mk());
        mutate(&serial, 0);
        mutate(&par, 0);
        serial.flush();
        par.par_flush(&coord);
        assert_snap_eq(&serial.snapshot(), &par.snapshot(), &format!("flush x{threads}"));

        mutate(&serial, 1);
        mutate(&par, 1);
        serial.compact();
        par.par_compact(&coord);
        assert_snap_eq(&serial.snapshot(), &par.snapshot(), &format!("compact x{threads}"));

        mutate(&serial, 2);
        mutate(&par, 2);
        serial.rebalance();
        par.par_rebalance(&coord);
        assert_snap_eq(&serial.snapshot(), &par.snapshot(), &format!("rebalance x{threads}"));

        // And the live sets agree with each other, id for id, row for row.
        let (ids_a, rows_a) = serial.collect_live(&serial.snapshot());
        let (ids_b, rows_b) = par.collect_live(&par.snapshot());
        assert_eq!(ids_a, ids_b, "threads={threads}: live ids");
        assert_eq!(rows_a.data, rows_b.data, "threads={threads}: live rows");
    }
}
