//! Gray-code curve 𝒢 (Faloutsos & Roseman [13]; paper §2.1).
//!
//! The order value is the *Gray-code rank* of the bit-interleaved
//! coordinates: `𝒢(i,j) = gray⁻¹(ℤ(i,j))`. Consecutive order values then
//! differ in exactly one bit of the interleaved word, i.e. one coordinate
//! changes by a power of two — smaller jumps than the Z-order's worst case,
//! though not the unit steps of Hilbert.

use super::zorder::{compact, spread};
use super::SpaceFillingCurve;

/// Gray code of `x` (binary-reflected).
#[inline]
pub fn gray(x: u64) -> u64 {
    x ^ (x >> 1)
}

/// Inverse Gray code (prefix-xor).
#[inline]
pub fn gray_inv(mut g: u64) -> u64 {
    g ^= g >> 1;
    g ^= g >> 2;
    g ^= g >> 4;
    g ^= g >> 8;
    g ^= g >> 16;
    g ^= g >> 32;
    g
}

/// The Gray-code curve.
#[derive(Copy, Clone, Debug)]
pub struct GrayCode;

impl SpaceFillingCurve for GrayCode {
    const NAME: &'static str = "gray";

    #[inline]
    fn order(i: u32, j: u32) -> u64 {
        gray_inv((spread(i) << 1) | spread(j))
    }

    #[inline]
    fn coords(c: u64) -> (u32, u32) {
        let z = gray(c);
        (compact(z >> 1), compact(z))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::forall;
    use std::collections::HashSet;

    #[test]
    fn gray_code_basics() {
        assert_eq!(gray(0), 0);
        assert_eq!(gray(1), 1);
        assert_eq!(gray(2), 3);
        assert_eq!(gray(3), 2);
        assert_eq!(gray(4), 6);
    }

    #[test]
    fn gray_inverse_property() {
        forall::<u64>("gray-inverse", |&x| gray_inv(gray(x)) == x && gray(gray_inv(x)) == x);
    }

    #[test]
    fn successive_gray_codes_differ_one_bit() {
        forall::<u64>("gray-one-bit", |&x| {
            let x = x & (u64::MAX >> 1);
            (gray(x) ^ gray(x + 1)).count_ones() == 1
        });
    }

    #[test]
    fn roundtrip_property() {
        forall::<(u32, u32)>("graycurve-roundtrip", |&(i, j)| {
            GrayCode::coords(GrayCode::order(i, j)) == (i, j)
        });
    }

    #[test]
    fn bijective_on_grid() {
        let vals: HashSet<u64> = (0..16u32)
            .flat_map(|i| (0..16u32).map(move |j| GrayCode::order(i, j)))
            .collect();
        assert_eq!(vals.len(), 256);
        assert_eq!(*vals.iter().max().unwrap(), 255);
    }

    #[test]
    fn steps_are_single_coordinate_power_of_two() {
        // The Gray-curve locality guarantee: one coordinate moves by ±2^k,
        // the other is unchanged.
        for c in 0..4095u64 {
            let (i0, j0) = GrayCode::coords(c);
            let (i1, j1) = GrayCode::coords(c + 1);
            let di = (i1 as i64 - i0 as i64).unsigned_abs();
            let dj = (j1 as i64 - j0 as i64).unsigned_abs();
            assert!(
                (di == 0 && dj.is_power_of_two()) || (dj == 0 && di.is_power_of_two()),
                "c={c}: ({i0},{j0})→({i1},{j1})"
            );
        }
    }
}
