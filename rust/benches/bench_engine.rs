//! Engine bench (ISSUE 1): scalar vs **batched** conversion, and
//! engine-routed enumeration vs the legacy repeated-`coords` filter loop,
//! across all curves. Emits JSON (`reports/bench_engine.json`) for the
//! perf trajectory in addition to the usual CSV.
//!
//! Expected shape: batched inverse conversion on order-sorted workloads
//! beats scalar by ~log(n) for Hilbert (Figure-5 stepping instead of one
//! Mealy inversion per value) and is at least on par everywhere else;
//! engine enumeration matches or beats the legacy path for every curve
//! (it is the same cover filter, minus the per-cell `O(log)` inversions
//! for Hilbert/Peano).

use sfc_mine::curves::engine::CurveMapper;
use sfc_mine::curves::gray::GrayCode;
use sfc_mine::curves::hilbert::Hilbert;
use sfc_mine::curves::peano::Peano;
use sfc_mine::curves::zorder::ZOrder;
use sfc_mine::curves::{CurveKind, SpaceFillingCurve};
use sfc_mine::util::bench::{Bench, Measurement};
use sfc_mine::util::table::Table;

/// The legacy enumeration path this bench regresses against: one
/// `coords` per cover order value (`O(n² log n)` for Hilbert/Peano),
/// filtering the in-grid cells — what `collect_filtered` did before the
/// engine.
fn legacy_collect<C: SpaceFillingCurve>(n: u32) -> Vec<(u32, u32)> {
    let cover = C::cover_side(n) as u64;
    let mut out = Vec::with_capacity((n as usize) * (n as usize));
    for c in 0..cover * cover {
        let (i, j) = C::coords(c);
        if i < n && j < n {
            out.push((i, j));
        }
    }
    out
}

fn write_json(bench: &Bench, path: &str) -> std::io::Result<()> {
    let mut s = String::from("[\n");
    for (idx, m) in bench.results().iter().enumerate() {
        if idx > 0 {
            s.push_str(",\n");
        }
        s.push_str(&format!(
            "  {{\"name\": \"{}\", \"median_ns\": {}, \"mad_ns\": {}, \"elements\": {}}}",
            m.name,
            m.median.as_nanos(),
            m.mad.as_nanos(),
            m.elements.unwrap_or(0)
        ));
    }
    s.push_str("\n]\n");
    if let Some(dir) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(path, s)
}

fn per_elem(m: &Measurement) -> f64 {
    m.median.as_nanos() as f64 / m.elements.unwrap_or(1) as f64
}

fn main() {
    let fast = std::env::var("SFC_BENCH_FAST").is_ok();
    let n_conv: u64 = if fast { 1 << 14 } else { 1 << 20 };
    let n_enum: u32 = if fast { 256 } else { 1024 };
    let mut bench = Bench::new();

    // --- Scalar vs batched conversion (order-sorted workload) --------------
    let mut conv = Table::new(vec![
        "curve",
        "scalar coords ns/val",
        "batched coords ns/val",
        "speedup",
        "scalar order ns/pair",
        "batched order ns/pair",
    ]);
    let orders: Vec<u64> = (0..n_conv).collect();
    for kind in CurveKind::ALL {
        let mapper = kind.mapper();
        let mut cells: Vec<(u32, u32)> = Vec::with_capacity(orders.len());
        let m_scalar = bench.throughput(
            &format!("engine/coords_scalar/{}", kind.name()),
            n_conv,
            || {
                let mut acc = 0u64;
                for &c in &orders {
                    let (i, j) = mapper.coords(c);
                    acc = acc.wrapping_add((i ^ j) as u64);
                }
                acc
            },
        );
        let m_batched = bench.throughput(
            &format!("engine/coords_batched/{}", kind.name()),
            n_conv,
            || {
                cells.clear();
                mapper.coords_batch(&orders, &mut cells);
                cells.len()
            },
        );
        // Forward direction on the cells we just produced (clear first:
        // the bench closure left its last fill in place).
        cells.clear();
        mapper.coords_batch(&orders, &mut cells);
        let mut hs: Vec<u64> = Vec::with_capacity(cells.len());
        let f_scalar = bench.throughput(
            &format!("engine/order_scalar/{}", kind.name()),
            n_conv,
            || {
                let mut acc = 0u64;
                for &(i, j) in &cells {
                    acc = acc.wrapping_add(mapper.order(i, j));
                }
                acc
            },
        );
        let f_batched = bench.throughput(
            &format!("engine/order_batched/{}", kind.name()),
            n_conv,
            || {
                hs.clear();
                mapper.order_batch(&cells, &mut hs);
                hs.len()
            },
        );
        conv.row(vec![
            kind.name().to_string(),
            format!("{:.2}", per_elem(&m_scalar)),
            format!("{:.2}", per_elem(&m_batched)),
            format!("{:.2}x", per_elem(&m_scalar) / per_elem(&m_batched)),
            format!("{:.2}", per_elem(&f_scalar)),
            format!("{:.2}", per_elem(&f_batched)),
        ]);
    }
    println!("\n== engine: scalar vs batched conversion ({n_conv} values) ==");
    print!("{}", conv.render());

    // --- Engine enumeration vs legacy repeated-coords filter ---------------
    // Non-power-of-two side so every curve actually filters its cover.
    let n = n_enum - n_enum / 5;
    let cells64 = (n as u64) * (n as u64);
    let mut enum_t = Table::new(vec!["curve", "legacy ns/cell", "engine ns/cell", "speedup"]);
    for kind in CurveKind::ALL {
        let m_legacy = bench.throughput(
            &format!("engine/enumerate_legacy/{}", kind.name()),
            cells64,
            || {
                let v = match kind {
                    CurveKind::Canonic => {
                        // The legacy path had a bespoke nested loop here;
                        // measure that faithfully.
                        let mut v = Vec::with_capacity((n as usize) * (n as usize));
                        for i in 0..n {
                            for j in 0..n {
                                v.push((i, j));
                            }
                        }
                        v
                    }
                    CurveKind::ZOrder => legacy_collect::<ZOrder>(n),
                    CurveKind::Gray => legacy_collect::<GrayCode>(n),
                    CurveKind::Hilbert => legacy_collect::<Hilbert>(n),
                    CurveKind::Peano => legacy_collect::<Peano>(n),
                };
                v.len()
            },
        );
        let m_engine = bench.throughput(
            &format!("engine/enumerate_engine/{}", kind.name()),
            cells64,
            || kind.enumerate(n).len(),
        );
        enum_t.row(vec![
            kind.name().to_string(),
            format!("{:.2}", per_elem(&m_legacy)),
            format!("{:.2}", per_elem(&m_engine)),
            format!("{:.2}x", per_elem(&m_legacy) / per_elem(&m_engine)),
        ]);
    }
    println!("\n== engine enumerate vs legacy collect_filtered ({n}x{n}) ==");
    print!("{}", enum_t.render());

    bench.write_csv("reports/bench_engine.csv").unwrap();
    write_json(&bench, "reports/bench_engine.json").unwrap();
    conv.write_csv("reports/engine_conversion.csv").unwrap();
    enum_t.write_csv("reports/engine_enumerate.csv").unwrap();
    println!("\nreports: reports/bench_engine.{{csv,json}}");
}
