//! Constant-time neighbor-finding on curve keys (Holzmüller,
//! "Efficient Neighbor-Finding on Space-Filling Curves", arXiv:1710.06384).
//!
//! A cell's geometric face neighbor differs from it by ±1 along one axis.
//! The classic way to reach it from a curve key is the full roundtrip —
//! decode the key to coordinates, increment, re-encode — which costs a
//! whole automaton descent per probe. This module computes the neighbor
//! **directly in curve-index space**:
//!
//! * **Hilbert** ([`NeighborPath::AutomatonWalk`]): a ±1 step along axis
//!   `a` flips a suffix of that axis's coordinate bits (the binary carry
//!   chain). In the orientation automaton that means only the digits at
//!   and below the carry's depth change, so the walker keeps a per-depth
//!   stack of packed `(entry, direction)` states (the same
//!   [`HilbertLut`](super::fastkey::HilbertLut) states PR 6 tabulated),
//!   ascends to the lowest common ancestor digit, splices the new
//!   coordinate column in, and re-encodes just the changed suffix:
//!
//!   ```text
//!     depth 0   w₀                         w₀          states[0] = start
//!     depth 1     w₁              ──►        w₁        states[1]
//!     depth 2       w₂   (carry t=1)           w₂'  ◄─ re-encode from
//!     depth 3         w₃                         w₃' ◄─ states[2] down
//!   ```
//!
//!   A carry of length `t` touches `t+1` digits; over a sequential walk
//!   the expected carry length is `Σ 2⁻ⁱ < 2`, so a step is amortized
//!   O(1) digit transitions — each one a single LUT lookup for d ≤ 8.
//!
//! * **Z-order / Gray** ([`NeighborPath::BitArithmetic`]): axis `a`'s
//!   bits sit at stride-`d` positions of the interleaved word, so ±1 is
//!   one masked carry: fill the foreign bits with ones, add the axis's
//!   least-significant mask bit, and the carry ripples only through that
//!   axis's column. Gray keys first map to the interleaved word via
//!   `gray(key)` (the Gray rank's inverse) and back with `gray_inv`.
//!
//! * **Canonic** ([`NeighborPath::MixedRadix`]): the row-major order is a
//!   mixed-radix numeral, so a neighbor is `key ± stride[a]` plus an
//!   overflow check on the axis digit.
//!
//! * **Anything else** ([`NeighborPath::CoordsRoundtrip`]): the
//!   decode–increment–encode fallback, kept as the reference semantics
//!   every fast path must match bit-for-bit (`tests/neighbor.rs`).
//!
//! Grid-edge neighbors are `None` — the operator never wraps around the
//! cube. [`NeighborFinder::stencil_keys`] composes steps into the
//! `3^d − 1` Chebyshev stencil (and wider boxes) by depth-first
//! step-and-undo, which the similarity join feeds straight into sorted
//! key-column probes instead of decomposing a ±ε window per cell.

use super::engine::{CurveMapperNd, DomainNd};
use super::fastkey::{hilbert_lut, HilbertLut, MaskLadder, MAX_LADDER_DIMS};
use super::gray::{gray, gray_inv};
use super::ndim::HilbertNd;

/// How a [`NeighborFinder`] reaches a neighbor key — the neighbor-side
/// mirror of [`KeyPath`](super::fastkey::KeyPath), with the same
/// introspection contract: tests assert the fast path engaged and no
/// silent roundtrip fallback occurred for d ≤ 8.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum NeighborPath {
    /// Hilbert state-stack walk over the packed automaton states.
    AutomatonWalk,
    /// Closed-form masked carry on the interleaved word (Z-order/Gray).
    BitArithmetic,
    /// Mixed-radix stride add/subtract (canonic row-major).
    MixedRadix,
    /// Decode → ±1 → re-encode through the mapper (reference fallback).
    CoordsRoundtrip,
}

impl NeighborPath {
    /// Short label for reports.
    pub fn name(self) -> &'static str {
        match self {
            NeighborPath::AutomatonWalk => "automaton-walk",
            NeighborPath::BitArithmetic => "bit-arithmetic",
            NeighborPath::MixedRadix => "mixed-radix",
            NeighborPath::CoordsRoundtrip => "coords-roundtrip",
        }
    }

    /// True for every path except the roundtrip fallback.
    pub fn is_fast(self) -> bool {
        self != NeighborPath::CoordsRoundtrip
    }
}

/// What a mapper tells the [`NeighborFinder`] about its key structure —
/// returned by [`CurveMapperNd::neighbor_ctx_nd`]. The default is
/// [`NeighborCtx::Roundtrip`]; the native Nd mappers override it with
/// their closed-form descriptions.
#[derive(Clone, Debug)]
pub enum NeighborCtx {
    /// Butz/Lawder Hilbert automaton over the `2^level` cube.
    Hilbert {
        /// Bits per axis.
        level: u32,
    },
    /// Plain d-way interleaving (axis 0 in the high digit bit); `gray`
    /// adds the Gray-rank transform around the interleaved word.
    Interleave {
        /// Bits per axis.
        level: u32,
        /// Key is the Gray rank of the interleaved word.
        gray: bool,
    },
    /// Mixed-radix row-major order over an axis-aligned box.
    MixedRadix {
        /// Per-axis extents.
        shape: Vec<u32>,
    },
    /// No structural shortcut — use the coords roundtrip.
    Roundtrip,
}

// ---------------------------------------------------------------------------
// Hilbert state-stack walker
// ---------------------------------------------------------------------------

/// Fixed per-mapper data for the Hilbert walk.
struct HilbertWalk {
    dims: u32,
    level: u32,
    lut: Option<&'static HilbertLut>,
    /// Packed start state `e·n + d` for this level's parity.
    start: usize,
    /// Column extract/splice ladder (`None` beyond [`MAX_LADDER_DIMS`],
    /// where the ≤ 7-digit loops are cheap anyway).
    lad: Option<MaskLadder>,
}

/// Mutable walk state: the key, its coordinate word (`interleave_rev`
/// layout: axis `k` at digit bit `k`), and the packed automaton state
/// *before* each top-down digit — `states[0]` is the start state,
/// `states[j]` the state entering depth-`j` digit (depth 0 = most
/// significant).
struct HilbertCursor {
    key: u64,
    z: u64,
    states: Vec<usize>,
}

impl HilbertWalk {
    fn new(dims: u32, level: u32) -> Self {
        let lut = hilbert_lut(dims as usize);
        let start = match lut {
            Some(t) => t.start_state(level),
            None => HilbertNd::new(dims as usize, level).packed_start(),
        };
        let lad = if (dims as usize) <= MAX_LADDER_DIMS {
            Some(MaskLadder::new(dims as usize, level))
        } else {
            None
        };
        HilbertWalk { dims, level, lut, start, lad }
    }

    #[inline]
    fn inv_step(&self, s: usize, w: u64) -> (u64, usize) {
        match self.lut {
            Some(t) => t.inv_step(s, w),
            None => HilbertNd::inv_step_scalar(s, w, self.dims),
        }
    }

    #[inline]
    fn fwd_step(&self, s: usize, l: u64) -> (u64, usize) {
        match self.lut {
            Some(t) => t.fwd_step(s, l),
            None => HilbertNd::fwd_step_scalar(s, l, self.dims),
        }
    }

    /// Decode `key` once: coordinate word + the full state stack.
    fn cursor(&self, key: u64) -> HilbertCursor {
        let m = self.level;
        let mut states = vec![0usize; m as usize + 1];
        let z = match self.lut {
            Some(t) => t.coords_word_states(key, m, &mut states),
            None => {
                let n = self.dims;
                let mask = (1u64 << n) - 1;
                states[0] = self.start;
                let mut s = self.start;
                let mut z = 0u64;
                let mut j = 0usize;
                let mut i = m;
                while i > 0 {
                    i -= 1;
                    let w = (key >> (i * n)) & mask;
                    let (l, s2) = self.inv_step(s, w);
                    z |= l << (i * n);
                    s = s2;
                    j += 1;
                    states[j] = s;
                }
                z
            }
        };
        HilbertCursor { key, z, states }
    }

    /// Axis `a`'s coordinate out of the `interleave_rev` word.
    #[inline]
    fn coord(&self, z: u64, axis: u32) -> u32 {
        match &self.lad {
            Some(lad) => lad.compact(z >> axis),
            None => {
                let mut c = 0u32;
                for i in 0..self.level {
                    c |= (((z >> (i * self.dims + axis)) & 1) as u32) << i;
                }
                c
            }
        }
    }

    /// Replace axis `a`'s coordinate column in `z` with `c`.
    #[inline]
    fn splice(&self, z: u64, axis: u32, c: u32) -> u64 {
        match &self.lad {
            Some(lad) => {
                let col = lad.spread(!0u32) << axis;
                (z & !col) | (lad.spread(c) << axis)
            }
            None => {
                let mut out = z;
                for i in 0..self.level {
                    let pos = i * self.dims + axis;
                    out = (out & !(1u64 << pos)) | ((((c >> i) & 1) as u64) << pos);
                }
                out
            }
        }
    }

    /// ±1 along `axis`; `false` (cursor unchanged) at the grid edge.
    /// Re-encodes only the digits at and below the carry depth.
    fn step(&self, cur: &mut HilbertCursor, axis: u32, dir: i32) -> bool {
        let n = self.dims;
        let m = self.level;
        let c = self.coord(cur.z, axis);
        // Carry length t: lowest coordinate bit the step leaves alone is
        // t; bits 0..=t all flip.
        let (nc, t) = if dir > 0 {
            if c == ((1u64 << m) - 1) as u32 {
                return false;
            }
            (c + 1, c.trailing_ones())
        } else {
            if c == 0 {
                return false;
            }
            (c - 1, c.trailing_zeros())
        };
        cur.z = self.splice(cur.z, axis, nc);
        // Digits above depth j0 kept the same coordinate bits on every
        // axis, so their order digits and states are unchanged; resume
        // the automaton from the stacked state at the carry depth.
        let j0 = (m - 1 - t) as usize;
        let mask = (1u64 << n) - 1;
        let mut s = cur.states[j0];
        let mut key = cur.key;
        for j in j0..m as usize {
            let i = (m as usize - 1 - j) as u32;
            let l = (cur.z >> (i * n)) & mask;
            let (w, s2) = self.fwd_step(s, l);
            key = (key & !(mask << (i * n))) | (w << (i * n));
            cur.states[j + 1] = s2;
            s = s2;
        }
        cur.key = key;
        true
    }
}

// ---------------------------------------------------------------------------
// Closed-form steppers
// ---------------------------------------------------------------------------

/// Masked-carry stepper on the interleaved word (Z-order, and Gray via
/// the rank transform).
struct InterleaveStep {
    dims: u32,
    level: u32,
    gray: bool,
    /// `axis_masks[a]`: the `level` bits of axis `a`'s column
    /// (positions `j·dims + (dims−1−a)`).
    axis_masks: Vec<u64>,
}

impl InterleaveStep {
    fn new(dims: u32, level: u32, gray: bool) -> Self {
        let axis_masks = (0..dims)
            .map(|a| {
                let lsb = 1u64 << (dims - 1 - a);
                (0..level).fold(0u64, |m, j| m | (lsb << (j * dims)))
            })
            .collect();
        InterleaveStep { dims, level, gray, axis_masks }
    }

    #[inline]
    fn step_key(&self, key: u64, axis: u32, dir: i32) -> Option<u64> {
        let z = if self.gray { gray(key) } else { key };
        let m = self.axis_masks[axis as usize];
        let lsb = 1u64 << (self.dims - 1 - axis);
        let full = if self.dims * self.level == 64 {
            !0u64
        } else {
            (1u64 << (self.dims * self.level)) - 1
        };
        let z2 = if dir > 0 {
            if z & m == m {
                return None; // axis coordinate is 2^level − 1
            }
            // Fill the foreign bit positions with ones so the +lsb carry
            // ripples straight through them to the next axis bit.
            ((z | (full & !m)).wrapping_add(lsb) & m) | (z & !m)
        } else {
            if z & m == 0 {
                return None; // axis coordinate is 0
            }
            // Isolated column minus lsb borrows through the zero gaps.
            ((z & m).wrapping_sub(lsb) & m) | (z & !m)
        };
        Some(if self.gray { gray_inv(z2) & full } else { z2 })
    }
}

/// Stride stepper on the canonic mixed-radix numeral.
struct MixedRadixStep {
    shape: Vec<u32>,
    strides: Vec<u64>,
}

impl MixedRadixStep {
    fn new(shape: Vec<u32>) -> Self {
        let d = shape.len();
        let mut strides = vec![1u64; d];
        for a in (0..d.saturating_sub(1)).rev() {
            strides[a] = strides[a + 1] * shape[a + 1] as u64;
        }
        MixedRadixStep { shape, strides }
    }

    #[inline]
    fn step_key(&self, key: u64, axis: u32, dir: i32) -> Option<u64> {
        let a = axis as usize;
        let digit = (key / self.strides[a]) % self.shape[a] as u64;
        if dir > 0 {
            if digit + 1 >= self.shape[a] as u64 {
                return None;
            }
            Some(key + self.strides[a])
        } else {
            if digit == 0 {
                return None;
            }
            Some(key - self.strides[a])
        }
    }
}

/// Decode–increment–encode fallback (the reference semantics).
struct RoundtripStep {
    /// Per-axis exclusive upper bounds; `None` for unbounded domains.
    shape: Option<Vec<u32>>,
}

// ---------------------------------------------------------------------------
// NeighborFinder
// ---------------------------------------------------------------------------

enum Engine {
    Hilbert(HilbertWalk),
    Interleave(InterleaveStep),
    MixedRadix(MixedRadixStep),
    Roundtrip(RoundtripStep),
}

/// Cursor over a cell key for repeated neighbor steps — the stateful
/// handle [`NeighborFinder::stencil_keys`] walks depth-first. Stateless
/// engines carry just the key; the Hilbert walk carries its coordinate
/// word and state stack.
enum Cursor {
    Hilbert(HilbertCursor),
    Key(u64),
}

/// Neighbor-rank operator over one [`CurveMapperNd`]: geometric face
/// neighbors computed directly on curve keys, selecting the fastest
/// structural path the mapper advertises (see the module docs and
/// [`NeighborPath`]).
pub struct NeighborFinder<'m> {
    mapper: &'m dyn CurveMapperNd,
    dims: usize,
    engine: Engine,
}

impl<'m> NeighborFinder<'m> {
    /// Build the operator for `mapper`, selecting the path from
    /// [`CurveMapperNd::neighbor_ctx_nd`].
    pub fn new(mapper: &'m dyn CurveMapperNd) -> Self {
        let dims = mapper.dims();
        let engine = match mapper.neighbor_ctx_nd() {
            NeighborCtx::Hilbert { level } => {
                Engine::Hilbert(HilbertWalk::new(dims as u32, level))
            }
            NeighborCtx::Interleave { level, gray } => {
                Engine::Interleave(InterleaveStep::new(dims as u32, level, gray))
            }
            NeighborCtx::MixedRadix { shape } => {
                Engine::MixedRadix(MixedRadixStep::new(shape))
            }
            NeighborCtx::Roundtrip => {
                let shape = match mapper.domain_nd() {
                    DomainNd::HyperRect { shape } => Some(shape),
                    DomainNd::Space { .. } => None,
                    DomainNd::SparseCube { level, dims, .. } => {
                        Some(vec![1u32 << level; dims])
                    }
                };
                Engine::Roundtrip(RoundtripStep { shape })
            }
        };
        NeighborFinder { mapper, dims, engine }
    }

    /// Number of dimensions.
    pub fn dims(&self) -> usize {
        self.dims
    }

    /// Which computation path neighbor keys take.
    pub fn path(&self) -> NeighborPath {
        match self.engine {
            Engine::Hilbert(_) => NeighborPath::AutomatonWalk,
            Engine::Interleave(_) => NeighborPath::BitArithmetic,
            Engine::MixedRadix(_) => NeighborPath::MixedRadix,
            Engine::Roundtrip(_) => NeighborPath::CoordsRoundtrip,
        }
    }

    #[inline]
    fn roundtrip_step(&self, rt: &RoundtripStep, key: u64, axis: usize, dir: i32) -> Option<u64> {
        let mut p = vec![0u32; self.dims];
        self.mapper.coords_nd(key, &mut p);
        let c = p[axis];
        if dir > 0 {
            let hi = rt.shape.as_ref().map_or(u32::MAX, |s| s[axis] - 1);
            if c >= hi {
                return None;
            }
            p[axis] = c + 1;
        } else {
            if c == 0 {
                return None;
            }
            p[axis] = c - 1;
        }
        Some(self.mapper.order_nd(&p))
    }

    /// Key of the face neighbor one cell along `axis` in direction
    /// `dir` (±1), or `None` at the grid edge — never a wraparound.
    pub fn neighbor_key(&self, key: u64, axis: usize, dir: i32) -> Option<u64> {
        debug_assert!(axis < self.dims && (dir == 1 || dir == -1));
        match &self.engine {
            Engine::Hilbert(w) => {
                let mut cur = w.cursor(key);
                w.step(&mut cur, axis as u32, dir).then_some(cur.key)
            }
            Engine::Interleave(s) => s.step_key(key, axis as u32, dir),
            Engine::MixedRadix(s) => s.step_key(key, axis as u32, dir),
            Engine::Roundtrip(rt) => self.roundtrip_step(rt, key, axis, dir),
        }
    }

    #[inline]
    fn make_cursor(&self, key: u64) -> Cursor {
        match &self.engine {
            Engine::Hilbert(w) => Cursor::Hilbert(w.cursor(key)),
            _ => Cursor::Key(key),
        }
    }

    #[inline]
    fn cursor_key(&self, cur: &Cursor) -> u64 {
        match cur {
            Cursor::Hilbert(c) => c.key,
            Cursor::Key(k) => *k,
        }
    }

    /// ±1 along `axis`; `false` leaves the cursor unchanged (grid edge).
    /// A successful step is exactly undone by the opposite step.
    #[inline]
    fn cursor_step(&self, cur: &mut Cursor, axis: usize, dir: i32) -> bool {
        match (&self.engine, cur) {
            (Engine::Hilbert(w), Cursor::Hilbert(c)) => w.step(c, axis as u32, dir),
            (Engine::Interleave(s), Cursor::Key(k)) => match s.step_key(*k, axis as u32, dir) {
                Some(nk) => {
                    *k = nk;
                    true
                }
                None => false,
            },
            (Engine::MixedRadix(s), Cursor::Key(k)) => match s.step_key(*k, axis as u32, dir) {
                Some(nk) => {
                    *k = nk;
                    true
                }
                None => false,
            },
            (Engine::Roundtrip(rt), Cursor::Key(k)) => {
                match self.roundtrip_step(rt, *k, axis, dir) {
                    Some(nk) => {
                        *k = nk;
                        true
                    }
                    None => false,
                }
            }
            _ => unreachable!("cursor kind matches engine kind"),
        }
    }

    /// All `2d` face neighbors of `key`, written as
    /// `out[2a] = axis a, −1` and `out[2a+1] = axis a, +1` (`None` at
    /// grid edges). One key decode is shared across all probes on the
    /// automaton-walk path.
    pub fn neighbors_keys(&self, key: u64, out: &mut Vec<Option<u64>>) {
        out.clear();
        out.reserve(2 * self.dims);
        let mut cur = self.make_cursor(key);
        for axis in 0..self.dims {
            for dir in [-1i32, 1] {
                if self.cursor_step(&mut cur, axis, dir) {
                    out.push(Some(self.cursor_key(&cur)));
                    let undone = self.cursor_step(&mut cur, axis, -dir);
                    debug_assert!(undone, "inverse of a successful step cannot hit an edge");
                } else {
                    out.push(None);
                }
            }
        }
    }

    /// Keys of every cell at per-axis offsets `lo_off[a] ..= hi_off[a]`
    /// from `key` (offsets need not be within ±1: wider boxes compose
    /// steps), skipping cells beyond the grid edge; `include_center`
    /// controls whether the zero-offset cell itself is emitted. Appends
    /// to `out` in depth-first order (callers sort when they need runs).
    pub fn stencil_keys(
        &self,
        key: u64,
        lo_off: &[i32],
        hi_off: &[i32],
        include_center: bool,
        out: &mut Vec<u64>,
    ) {
        debug_assert_eq!(lo_off.len(), self.dims);
        debug_assert_eq!(hi_off.len(), self.dims);
        debug_assert!(lo_off.iter().all(|&o| o <= 0));
        debug_assert!(hi_off.iter().all(|&o| o >= 0));
        let mut cur = self.make_cursor(key);
        self.stencil_rec(&mut cur, 0, lo_off, hi_off, include_center, true, out);
    }

    /// The `3^d − 1` Chebyshev stencil: every cell within one step per
    /// axis, excluding the center — the join's candidate cell set.
    pub fn chebyshev_keys(&self, key: u64, out: &mut Vec<u64>) {
        let lo = vec![-1i32; self.dims];
        let hi = vec![1i32; self.dims];
        self.stencil_keys(key, &lo, &hi, false, out);
    }

    fn stencil_rec(
        &self,
        cur: &mut Cursor,
        axis: usize,
        lo_off: &[i32],
        hi_off: &[i32],
        include_center: bool,
        is_center: bool,
        out: &mut Vec<u64>,
    ) {
        if axis == self.dims {
            if include_center || !is_center {
                out.push(self.cursor_key(cur));
            }
            return;
        }
        // Offset 0 first, then walk each direction with undo — the
        // cursor returns to the axis origin after both sweeps.
        self.stencil_rec(cur, axis + 1, lo_off, hi_off, include_center, is_center, out);
        for (dir, span) in [(-1i32, -lo_off[axis]), (1, hi_off[axis])] {
            let mut done = 0;
            for _ in 0..span {
                if !self.cursor_step(cur, axis, dir) {
                    break; // grid edge: farther offsets are off-grid too
                }
                done += 1;
                self.stencil_rec(cur, axis + 1, lo_off, hi_off, include_center, false, out);
            }
            for _ in 0..done {
                let undone = self.cursor_step(cur, axis, -dir);
                debug_assert!(undone);
            }
        }
    }
}

/// Convenience one-shot: the face neighbor of `key` under `mapper`
/// (builds a throwaway [`NeighborFinder`]; hoist one out of loops).
pub fn neighbor_key(mapper: &dyn CurveMapperNd, key: u64, axis: usize, dir: i32) -> Option<u64> {
    NeighborFinder::new(mapper).neighbor_key(key, axis, dir)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::curves::ndim::{CanonicNd, GrayNd, HilbertNd, ZOrderNd};

    /// Reference: decode, ±1, re-encode, with edge checks from the
    /// domain shape.
    fn roundtrip_ref(
        m: &dyn CurveMapperNd,
        key: u64,
        axis: usize,
        dir: i32,
    ) -> Option<u64> {
        let d = m.dims();
        let shape = match m.domain_nd() {
            DomainNd::HyperRect { shape } => shape,
            _ => panic!("test mappers are rects"),
        };
        let mut p = vec![0u32; d];
        m.coords_nd(key, &mut p);
        let c = p[axis] as i64 + dir as i64;
        if c < 0 || c >= shape[axis] as i64 {
            return None;
        }
        p[axis] = c as u32;
        Some(m.order_nd(&p))
    }

    #[test]
    fn hilbert_walk_matches_roundtrip_small_exhaustive() {
        for (dims, level) in [(2usize, 3u32), (2, 4), (3, 2), (3, 3), (4, 2)] {
            let m = HilbertNd::new(dims, level);
            let span = 1u64 << (dims as u32 * level);
            let f = NeighborFinder::new(&m);
            assert_eq!(f.path(), NeighborPath::AutomatonWalk);
            for key in 0..span {
                for axis in 0..dims {
                    for dir in [-1, 1] {
                        assert_eq!(
                            f.neighbor_key(key, axis, dir),
                            roundtrip_ref(&m, key, axis, dir),
                            "d={dims} m={level} key={key} axis={axis} dir={dir}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn closed_forms_match_roundtrip() {
        let z = ZOrderNd::new(3, 4);
        let g = GrayNd::new(3, 4);
        let c = CanonicNd::new(vec![5, 3, 7]);
        for (m, path) in [
            (&z as &dyn CurveMapperNd, NeighborPath::BitArithmetic),
            (&g as &dyn CurveMapperNd, NeighborPath::BitArithmetic),
            (&c as &dyn CurveMapperNd, NeighborPath::MixedRadix),
        ] {
            let f = NeighborFinder::new(m);
            assert_eq!(f.path(), path, "{}", m.name_nd());
            let span = m.order_span_nd().unwrap();
            for key in 0..span {
                for axis in 0..3 {
                    for dir in [-1, 1] {
                        assert_eq!(
                            f.neighbor_key(key, axis, dir),
                            roundtrip_ref(m, key, axis, dir),
                            "{} key={key} axis={axis} dir={dir}",
                            m.name_nd()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn batched_face_neighbors_share_one_decode() {
        let m = HilbertNd::new(3, 4);
        let f = NeighborFinder::new(&m);
        let mut out = Vec::new();
        for key in [0u64, 1, 100, 4095] {
            f.neighbors_keys(key, &mut out);
            assert_eq!(out.len(), 6);
            for axis in 0..3 {
                assert_eq!(out[2 * axis], roundtrip_ref(&m, key, axis, -1));
                assert_eq!(out[2 * axis + 1], roundtrip_ref(&m, key, axis, 1));
            }
        }
    }

    #[test]
    fn chebyshev_stencil_has_full_count_in_the_interior() {
        let m = HilbertNd::new(3, 3);
        let f = NeighborFinder::new(&m);
        // An interior cell: all coordinates strictly inside the grid.
        let key = m.order_point(&[3, 4, 2]);
        let mut out = Vec::new();
        f.chebyshev_keys(key, &mut out);
        assert_eq!(out.len(), 26);
        out.sort_unstable();
        out.dedup();
        assert_eq!(out.len(), 26, "stencil keys must be distinct");
        assert!(!out.contains(&key), "center excluded");
    }
}
