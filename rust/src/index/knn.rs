//! Shared expanding-window k-nearest-neighbor driver.
//!
//! Both [`SfcIndex`](super::SfcIndex) and [`SfcStore`](super::SfcStore)
//! answer kNN the same way: a centered L∞ window of radius `r` is
//! complete for any answer distance `≤ r`, so the window doubles until
//! the heap's k-th distance is covered (or the data's bounding box is).
//! The window-probe itself is the structure-specific part, injected as a
//! closure; the radius schedule, heap bookkeeping and termination rule
//! live here once.

use std::collections::BinaryHeap;

/// A kNN candidate in the query's max-heap (ordered by distance, ties by
/// id, via total order on the floats).
#[derive(Copy, Clone, Debug)]
pub(crate) struct Neighbor {
    pub dist: f32,
    pub id: u32,
}

impl PartialEq for Neighbor {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == std::cmp::Ordering::Equal
    }
}

impl Eq for Neighbor {}

impl PartialOrd for Neighbor {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Neighbor {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.dist
            .total_cmp(&other.dist)
            .then(self.id.cmp(&other.id))
    }
}

/// The `k` nearest neighbors of `q` by Euclidean distance, sorted
/// ascending as `(id, distance)`.
///
/// `for_window(lo, hi, emit)` must call `emit(id, row)` for every point
/// whose coordinates lie inside the closed float window `[lo, hi]` —
/// exactly once per live point. `cover_lo`/`cover_hi` bound the data
/// (once the window covers them the scan was exhaustive), and `start_r`
/// seeds the radius (callers pass the largest quantization cell width;
/// `0` is bumped to a small positive epsilon so degenerate data still
/// makes progress).
pub(crate) fn expanding_knn(
    q: &[f32],
    k: usize,
    start_r: f32,
    cover_lo: &[f32],
    cover_hi: &[f32],
    mut for_window: impl FnMut(&[f32], &[f32], &mut dyn FnMut(u32, &[f32])),
) -> Vec<(u32, f32)> {
    if k == 0 {
        return Vec::new();
    }
    let dims = q.len();
    let mut r = start_r;
    if r <= 0.0 {
        r = 1e-6;
    }
    let mut lo = vec![0.0f32; dims];
    let mut hi = vec![0.0f32; dims];
    loop {
        for a in 0..dims {
            lo[a] = q[a] - r;
            hi[a] = q[a] + r;
        }
        let mut heap: BinaryHeap<Neighbor> = BinaryHeap::with_capacity(k + 1);
        for_window(&lo, &hi, &mut |id, row| {
            let dist2: f32 = row.iter().zip(q).map(|(&a, &b)| (a - b) * (a - b)).sum();
            heap.push(Neighbor { dist: dist2.sqrt(), id });
            if heap.len() > k {
                heap.pop();
            }
        });
        let covers = (0..dims).all(|a| lo[a] <= cover_lo[a] && hi[a] >= cover_hi[a]);
        let done = heap.len() == k && heap.peek().map(|n| n.dist <= r).unwrap_or(false);
        if covers || done {
            let mut best = heap.into_vec();
            best.sort();
            return best.into_iter().map(|n| (n.id, n.dist)).collect();
        }
        r *= 2.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn finds_true_neighbors_on_a_line() {
        let points: Vec<f32> = (0..20).map(|i| i as f32).collect();
        let got = expanding_knn(&[7.2], 3, 1.0, &[0.0], &[19.0], |lo, hi, emit| {
            for (id, &x) in points.iter().enumerate() {
                if x >= lo[0] && x <= hi[0] {
                    emit(id as u32, std::slice::from_ref(&x));
                }
            }
        });
        assert_eq!(got.len(), 3);
        assert_eq!(got[0].0, 7);
        assert!((got[0].1 - 0.2).abs() < 1e-6);
        assert_eq!(got[1].0, 8);
        assert_eq!(got[2].0, 6);
    }

    #[test]
    fn fewer_points_than_k_terminates_via_cover() {
        let got = expanding_knn(&[100.0], 5, 0.0, &[0.0], &[1.0], |lo, hi, emit| {
            if lo[0] <= 0.5 && hi[0] >= 0.5 {
                emit(0, &[0.5]);
            }
        });
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].0, 0);
    }

    #[test]
    fn k_zero_is_empty() {
        assert!(expanding_knn(&[0.0], 0, 1.0, &[0.0], &[1.0], |_, _, _| ()).is_empty());
    }
}
