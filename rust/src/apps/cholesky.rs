//! Cholesky decomposition `A = L·Lᵀ` (paper §7).
//!
//! Blocked right-looking factorization. Within one step `k`, the trailing
//! update blocks `(i, j)` with `k < j ≤ i` are mutually independent — the
//! "maximum parts compatible with an arbitrary traversal" the paper
//! describes — so that sub-grid can be walked in any order:
//!
//! * [`cholesky_blocked`] with [`TrailingOrder::Canonic`] — nested loops
//!   (the cache-conscious baseline; block size is the tuning knob);
//! * [`TrailingOrder::Hilbert`] — the engine's [`FgfMapper`] over the
//!   trailing triangle (`Intersect(LowerTriangleIncl, MinBounds)`),
//!   cache-oblivious with jump-over.
//!
//! The unblocked [`cholesky_unblocked`] is the correctness reference.

use super::Matrix;
use crate::curves::engine::FgfMapper;
use crate::curves::fgf::{Intersect, LowerTriangleIncl, MinBounds};
use crate::{Error, Result};

/// Traversal order of the trailing-update block grid.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum TrailingOrder {
    /// Row-major nested block loops.
    Canonic,
    /// FGF-Hilbert over the trailing lower triangle.
    Hilbert,
}

/// Unblocked (scalar) Cholesky; the lower triangle of `a` is overwritten
/// with `L`, the strict upper triangle is zeroed. Errors on a non-PD input.
pub fn cholesky_unblocked(a: &mut Matrix) -> Result<()> {
    assert_eq!(a.rows, a.cols, "Cholesky needs a square matrix");
    let n = a.rows;
    for j in 0..n {
        let mut diag = a.at(j, j);
        for k in 0..j {
            let v = a.at(j, k);
            diag -= v * v;
        }
        if diag <= 0.0 {
            return Err(Error::Numerical(format!(
                "matrix not positive definite at pivot {j} (d={diag})"
            )));
        }
        let ljj = diag.sqrt();
        *a.at_mut(j, j) = ljj;
        for i in j + 1..n {
            let mut v = a.at(i, j);
            for k in 0..j {
                v -= a.at(i, k) * a.at(j, k);
            }
            *a.at_mut(i, j) = v / ljj;
        }
        for i in 0..j {
            *a.at_mut(i, j) = 0.0;
        }
    }
    Ok(())
}

/// Blocked right-looking Cholesky with block size `t`; the trailing update
/// is traversed in the given order.
pub fn cholesky_blocked(a: &mut Matrix, t: usize, order: TrailingOrder) -> Result<()> {
    assert_eq!(a.rows, a.cols);
    assert!(t > 0);
    let n = a.rows;
    let nb = n.div_ceil(t);
    for kb in 0..nb {
        let k0 = kb * t;
        let k1 = (k0 + t).min(n);
        // 1. Factor the diagonal block in place.
        factor_diag(a, k0, k1)?;
        // 2. Panel solve: rows below the diagonal block.
        for ib in kb + 1..nb {
            let i0 = ib * t;
            let i1 = (i0 + t).min(n);
            panel_solve(a, k0, k1, i0, i1);
        }
        // 3. Trailing update: independent blocks, any traversal order.
        let update = |ib: usize, jb: usize, a: &mut Matrix| {
            let i0 = ib * t;
            let i1 = (i0 + t).min(n);
            let j0 = jb * t;
            let j1 = (j0 + t).min(n);
            trailing_update(a, k0, k1, i0, i1, j0, j1);
        };
        match order {
            TrailingOrder::Canonic => {
                for ib in kb + 1..nb {
                    for jb in kb + 1..=ib {
                        update(ib, jb, a);
                    }
                }
            }
            TrailingOrder::Hilbert => {
                let level = (nb as u32).next_power_of_two().trailing_zeros();
                let region = Intersect(
                    Intersect(LowerTriangleIncl, MinBounds {
                        i_min: (kb + 1) as u32,
                        j_min: (kb + 1) as u32,
                    }),
                    crate::curves::fgf::Rect { n: nb as u32, m: nb as u32 },
                );
                let mapper = FgfMapper::new(level, region);
                mapper.traverse(|ib, jb, _h| {
                    update(ib as usize, jb as usize, a);
                });
            }
        }
    }
    // Zero the strict upper triangle for a clean L.
    for i in 0..n {
        for j in i + 1..n {
            *a.at_mut(i, j) = 0.0;
        }
    }
    Ok(())
}

/// Factor `A[k0..k1, k0..k1]` in place (unblocked).
fn factor_diag(a: &mut Matrix, k0: usize, k1: usize) -> Result<()> {
    for j in k0..k1 {
        let mut diag = a.at(j, j);
        for k in k0..j {
            let v = a.at(j, k);
            diag -= v * v;
        }
        if diag <= 0.0 {
            return Err(Error::Numerical(format!(
                "matrix not positive definite at pivot {j} (d={diag})"
            )));
        }
        let ljj = diag.sqrt();
        *a.at_mut(j, j) = ljj;
        for i in j + 1..k1 {
            let mut v = a.at(i, j);
            for k in k0..j {
                v -= a.at(i, k) * a.at(j, k);
            }
            *a.at_mut(i, j) = v / ljj;
        }
    }
    Ok(())
}

/// Solve `X · L[k]ᵀ = A[i0..i1, k0..k1]` in place (forward substitution
/// against the already-factored diagonal block).
fn panel_solve(a: &mut Matrix, k0: usize, k1: usize, i0: usize, i1: usize) {
    for i in i0..i1 {
        for j in k0..k1 {
            let mut v = a.at(i, j);
            for k in k0..j {
                v -= a.at(i, k) * a.at(j, k);
            }
            *a.at_mut(i, j) = v / a.at(j, j);
        }
    }
}

/// `A[i0..i1, j0..j1] -= L[i0..i1, k0..k1] · L[j0..j1, k0..k1]ᵀ`, lower
/// part only where the block straddles the diagonal.
fn trailing_update(
    a: &mut Matrix,
    k0: usize,
    k1: usize,
    i0: usize,
    i1: usize,
    j0: usize,
    j1: usize,
) {
    for i in i0..i1 {
        let jmax = j1.min(i + 1); // stay in the lower triangle
        for j in j0..jmax {
            let mut v = a.at(i, j);
            for k in k0..k1 {
                v -= a.at(i, k) * a.at(j, k);
            }
            *a.at_mut(i, j) = v;
        }
    }
}

/// Build a well-conditioned SPD test matrix `M·Mᵀ + n·I`.
pub fn random_spd(n: usize, seed: u64) -> Matrix {
    let m = Matrix::random(n, n, seed, -1.0, 1.0);
    let mut a = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            let mut s = 0.0;
            for k in 0..n {
                s += m.at(i, k) * m.at(j, k);
            }
            *a.at_mut(i, j) = s + if i == j { n as f32 } else { 0.0 };
        }
    }
    a
}

/// Verify `L·Lᵀ ≈ A` (max-abs residual).
pub fn residual(l: &Matrix, a: &Matrix) -> f32 {
    let n = a.rows;
    let mut worst = 0.0f32;
    for i in 0..n {
        for j in 0..=i {
            let mut s = 0.0;
            for k in 0..=j {
                s += l.at(i, k) * l.at(j, k);
            }
            worst = worst.max((s - a.at(i, j)).abs());
        }
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unblocked_factors_spd() {
        let a = random_spd(24, 7);
        let mut l = a.clone();
        cholesky_unblocked(&mut l).unwrap();
        assert!(residual(&l, &a) < 1e-3, "residual {}", residual(&l, &a));
    }

    #[test]
    fn blocked_variants_match_unblocked() {
        for n in [16usize, 30, 65] {
            let a = random_spd(n, 11);
            let mut reference = a.clone();
            cholesky_unblocked(&mut reference).unwrap();
            for order in [TrailingOrder::Canonic, TrailingOrder::Hilbert] {
                for t in [4usize, 8, 16] {
                    let mut l = a.clone();
                    cholesky_blocked(&mut l, t, order).unwrap();
                    let d = l.max_abs_diff(&reference);
                    assert!(d < 1e-3, "n={n} t={t} {order:?}: diff {d}");
                }
            }
        }
    }

    #[test]
    fn non_pd_detected() {
        let mut a = Matrix::from_fn(3, 3, |i, j| if i == j { -1.0 } else { 0.0 });
        assert!(cholesky_unblocked(&mut a).is_err());
        let mut b = Matrix::from_fn(3, 3, |i, j| if i == j { -1.0 } else { 0.0 });
        assert!(cholesky_blocked(&mut b, 2, TrailingOrder::Hilbert).is_err());
    }

    #[test]
    fn upper_triangle_zeroed() {
        let a = random_spd(9, 3);
        let mut l = a.clone();
        cholesky_blocked(&mut l, 4, TrailingOrder::Hilbert).unwrap();
        for i in 0..9 {
            for j in i + 1..9 {
                assert_eq!(l.at(i, j), 0.0);
            }
        }
    }

    #[test]
    fn one_by_one() {
        let mut a = Matrix { rows: 1, cols: 1, data: vec![4.0] };
        cholesky_blocked(&mut a, 8, TrailingOrder::Hilbert).unwrap();
        assert_eq!(a.data, vec![2.0]);
    }
}
