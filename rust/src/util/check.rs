//! Mini property-testing harness (proptest stand-in; see DESIGN.md §3).
//!
//! Deterministic: every property runs a fixed number of cases from a seeded
//! [`Rng`](crate::util::rng::Rng), ramping generator "size" from small to
//! large so that boundary cases come first. On failure the harness performs
//! a simple halving shrink on every integer component and reports the
//! minimal failing case it found.

use crate::util::rng::Rng;

/// Number of cases per property (can be raised via `SFC_CHECK_CASES`).
pub fn default_cases() -> usize {
    std::env::var("SFC_CHECK_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(256)
}

/// A generated value together with the "size" budget it was drawn at.
pub trait Gen: Clone + std::fmt::Debug {
    /// Draw a value; `size` ramps 0..=100 over the run.
    fn gen(rng: &mut Rng, size: u32) -> Self;
    /// Candidate shrinks, simplest first. Default: none.
    fn shrinks(&self) -> Vec<Self> {
        Vec::new()
    }
}

impl Gen for u32 {
    fn gen(rng: &mut Rng, size: u32) -> Self {
        // Ramp the magnitude: small sizes draw tiny values.
        let max = 1u64 << (2 + (size as u64 * 28) / 100); // 4 .. 2^30
        rng.below(max) as u32
    }
    fn shrinks(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self > 0 {
            out.push(0);
            out.push(self / 2);
            out.push(self - 1);
        }
        out.dedup();
        out
    }
}

impl Gen for u64 {
    fn gen(rng: &mut Rng, size: u32) -> Self {
        let max = 1u64 << (2 + (size as u64 * 58) / 100);
        rng.below(max)
    }
    fn shrinks(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self > 0 {
            out.push(0);
            out.push(self / 2);
            out.push(self - 1);
        }
        out.dedup();
        out
    }
}

impl Gen for bool {
    fn gen(rng: &mut Rng, _size: u32) -> Self {
        rng.bool(0.5)
    }
    fn shrinks(&self) -> Vec<Self> {
        if *self { vec![false] } else { vec![] }
    }
}

impl<A: Gen, B: Gen> Gen for (A, B) {
    fn gen(rng: &mut Rng, size: u32) -> Self {
        (A::gen(rng, size), B::gen(rng, size))
    }
    fn shrinks(&self) -> Vec<Self> {
        let mut out: Vec<Self> = self
            .0
            .shrinks()
            .into_iter()
            .map(|a| (a, self.1.clone()))
            .collect();
        out.extend(self.1.shrinks().into_iter().map(|b| (self.0.clone(), b)));
        out
    }
}

impl<A: Gen, B: Gen, C: Gen> Gen for (A, B, C) {
    fn gen(rng: &mut Rng, size: u32) -> Self {
        (A::gen(rng, size), B::gen(rng, size), C::gen(rng, size))
    }
    fn shrinks(&self) -> Vec<Self> {
        let mut out: Vec<Self> = self
            .0
            .shrinks()
            .into_iter()
            .map(|a| (a, self.1.clone(), self.2.clone()))
            .collect();
        out.extend(
            self.1
                .shrinks()
                .into_iter()
                .map(|b| (self.0.clone(), b, self.2.clone())),
        );
        out.extend(
            self.2
                .shrinks()
                .into_iter()
                .map(|c| (self.0.clone(), self.1.clone(), c)),
        );
        out
    }
}

/// Run `prop` over `cases` generated inputs; panic with the minimal failing
/// case if any input violates the property.
pub fn forall<T: Gen>(name: &str, prop: impl Fn(&T) -> bool) {
    forall_seeded(name, 0xC0FFEE, default_cases(), prop)
}

/// [`forall`] with explicit seed and case count.
pub fn forall_seeded<T: Gen>(name: &str, seed: u64, cases: usize, prop: impl Fn(&T) -> bool) {
    let mut rng = Rng::new(seed ^ fxhash(name));
    for case in 0..cases {
        let size = ((case * 100) / cases.max(1)) as u32;
        let input = T::gen(&mut rng, size);
        if !prop(&input) {
            let minimal = shrink(input, &prop);
            panic!("property '{name}' failed; minimal counterexample: {minimal:?}");
        }
    }
}

/// Greedy shrink: repeatedly take the first shrink candidate that still
/// fails, until none do.
fn shrink<T: Gen>(mut failing: T, prop: &impl Fn(&T) -> bool) -> T {
    'outer: loop {
        for cand in failing.shrinks() {
            if !prop(&cand) {
                failing = cand;
                continue 'outer;
            }
        }
        return failing;
    }
}

/// Tiny FNV-style string hash to decorrelate per-property streams.
fn fxhash(s: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall::<(u32, u32)>("add-commutes", |&(a, b)| {
            a.wrapping_add(b) == b.wrapping_add(a)
        });
    }

    #[test]
    #[should_panic(expected = "minimal counterexample")]
    fn failing_property_reports() {
        forall::<u32>("all-small", |&x| x < 5);
    }

    #[test]
    fn shrink_reaches_minimal() {
        // property fails for x >= 17; the shrinker must land exactly on 17.
        let failing = 900_000u32;
        let min = shrink(failing, &|&x: &u32| x < 17);
        assert_eq!(min, 17);
    }

    #[test]
    fn size_ramp_generates_small_values_first() {
        let mut rng = Rng::new(1);
        let early = u32::gen(&mut rng, 0);
        assert!(early < 4, "size-0 draws are tiny, got {early}");
    }
}
