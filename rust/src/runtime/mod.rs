//! The PJRT runtime: loads AOT-compiled JAX/Pallas artifacts (HLO text
//! emitted once by `python/compile/aot.py`) and executes them from the
//! Rust hot path. Python is never on the request path.
//!
//! Interchange format is **HLO text**, not serialized `HloModuleProto` —
//! jax ≥ 0.5 emits protos with 64-bit instruction ids that the crate's
//! xla_extension 0.5.1 rejects; the text parser reassigns ids (see
//! DESIGN.md and `/opt/xla-example/README.md`).
//!
//! The real backend is gated behind the `pjrt` cargo feature (it links
//! the vendored `xla` crate); default builds ship a dependency-free stub
//! [`Engine`] with the same API surface.

pub mod artifact;
pub mod engine;

pub use artifact::{Artifact, Manifest};
pub use engine::Engine;
