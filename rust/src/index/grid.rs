//! Uniform grid index over the first two dimensions of a point set —
//! the **2-D projection baseline**.
//!
//! The original similarity-join substrate: points are bucketed into
//! square cells of side `eps` (over dims 0 and 1). Any join pair within
//! distance `eps` in the *full* space is also within `eps` in the 2-d
//! projection, so the candidate set "all pairs from cells within
//! Chebyshev distance 1" is conservative (no false dismissals) — the
//! same role the hierarchical index of [20] plays for the paper's FGF
//! join. It is, however, *loose* for d ≥ 3: points far apart in the
//! unindexed dimensions share cells. The full-dimensional
//! [`GridIndexNd`](super::GridIndexNd) tightens the candidate set with
//! every indexed dimension and is what the join drivers use; this index
//! remains as the measured baseline
//! ([`join_grid_projected`](crate::apps::simjoin::join_grid_projected)).
//!
//! [`GridIndex::hilbert_cell_ranks`] numbers the non-empty cells along
//! their spatial Hilbert order through the engine's batched conversion,
//! which is what transfers curve locality onto index-driven workloads
//! (the similarity join's cell-pair grid).

use crate::apps::Matrix;
use crate::curves::engine::CurveMapper;
use crate::curves::CurveKind;

/// A grid cell's integer coordinates (0-based after offsetting).
pub type Cell = (u32, u32);

/// Uniform grid index.
#[derive(Clone, Debug)]
pub struct GridIndex {
    /// Cell side length (= join radius).
    pub eps: f32,
    /// Minimum corner of the bounding box (dims 0, 1).
    pub origin: (f32, f32),
    /// Grid extent in cells per axis.
    pub extent: (u32, u32),
    /// Non-empty cells with their point lists, sorted by cell coordinate.
    cells: Vec<(Cell, Vec<u32>)>,
}

impl GridIndex {
    /// Build the index for join radius `eps` (> 0) over `points` (`n×d`,
    /// `d ≥ 2`) — the shared [`axis_bounds`](super::axis_bounds) scan +
    /// [`bucket_cells`](super::bucket_cells) core, projected onto the
    /// first two dimensions.
    pub fn build(points: &Matrix, eps: f32) -> Self {
        assert!(eps > 0.0, "eps must be positive");
        assert!(points.cols >= 2, "grid index needs ≥ 2 dimensions");
        let (min, max) = match super::axis_bounds(points, 2) {
            Some(b) => b,
            None => {
                return GridIndex {
                    eps,
                    origin: (0.0, 0.0),
                    extent: (0, 0),
                    cells: Vec::new(),
                }
            }
        };
        let to_cell = |v: f32, lo: f32| -> u32 { ((v - lo) / eps).floor() as u32 };
        let extent = (to_cell(max[0], min[0]) + 1, to_cell(max[1], min[1]) + 1);
        // Lexicographic CellNd order equals the tuple sort order, so the
        // shared bucketing hands back cells already sorted for this
        // index's binary searches.
        let cells = super::bucket_cells(points, eps, &min, 2)
            .into_iter()
            .map(|(c, v)| ((c[0], c[1]), v))
            .collect();
        GridIndex {
            eps,
            origin: (min[0], min[1]),
            extent,
            cells,
        }
    }

    /// Number of non-empty cells.
    pub fn len(&self) -> usize {
        self.cells.len()
    }

    /// True if the index holds no points.
    pub fn is_empty(&self) -> bool {
        self.cells.is_empty()
    }

    /// Non-empty cells, sorted by coordinate.
    pub fn cells(&self) -> &[(Cell, Vec<u32>)] {
        &self.cells
    }

    /// Points of the cell at `coord`, if non-empty.
    pub fn cell_points(&self, coord: Cell) -> Option<&[u32]> {
        self.cells
            .binary_search_by_key(&coord, |&(c, _)| c)
            .ok()
            .map(|idx| self.cells[idx].1.as_slice())
    }

    /// Are two cells within Chebyshev distance 1 (i.e. a candidate pair)?
    pub fn neighbors(a: Cell, b: Cell) -> bool {
        a.0.abs_diff(b.0) <= 1 && a.1.abs_diff(b.1) <= 1
    }

    /// Number the non-empty cells along their spatial Hilbert order.
    ///
    /// Returns `(order, rank)`: `order[pos]` is the cells-index of the
    /// `pos`-th cell in Hilbert order, and `rank[idx]` is the Hilbert
    /// position of cells-index `idx` (mutually inverse permutations).
    /// Cell coordinates convert through the engine's batched path, so the
    /// automaton setup is amortised across the whole index.
    pub fn hilbert_cell_ranks(&self) -> (Vec<u32>, Vec<u32>) {
        let mapper = CurveKind::Hilbert.mapper();
        let coords: Vec<Cell> = self.cells.iter().map(|&(c, _)| c).collect();
        let mut hs = Vec::new();
        mapper.order_batch(&coords, &mut hs);
        let mut order: Vec<u32> = (0..self.cells.len() as u32).collect();
        order.sort_by_key(|&idx| hs[idx as usize]);
        let mut rank = vec![0u32; self.cells.len()];
        for (pos, &idx) in order.iter().enumerate() {
            rank[idx as usize] = pos as u32;
        }
        (order, rank)
    }

    /// Average points per non-empty cell.
    pub fn mean_occupancy(&self) -> f64 {
        if self.cells.is_empty() {
            0.0
        } else {
            self.cells.iter().map(|(_, v)| v.len() as f64).sum::<f64>() / self.cells.len() as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pts(rows: &[[f32; 2]]) -> Matrix {
        Matrix::from_fn(rows.len(), 2, |i, j| rows[i][j])
    }

    #[test]
    fn buckets_points_correctly() {
        let m = pts(&[[0.1, 0.1], [0.2, 0.15], [2.5, 0.1], [0.1, 2.5]]);
        let g = GridIndex::build(&m, 1.0);
        assert_eq!(g.len(), 3);
        assert_eq!(g.cell_points((0, 0)).unwrap(), &[0, 1]);
        assert_eq!(g.cell_points((2, 0)).unwrap(), &[2]);
        assert_eq!(g.cell_points((0, 2)).unwrap(), &[3]);
        assert_eq!(g.extent, (3, 3));
    }

    #[test]
    fn every_point_in_exactly_one_cell() {
        let m = Matrix::random(500, 4, 3, -10.0, 10.0);
        let g = GridIndex::build(&m, 0.7);
        let total: usize = g.cells().iter().map(|(_, v)| v.len()).sum();
        assert_eq!(total, 500);
        let mut seen = std::collections::HashSet::new();
        for (_, v) in g.cells() {
            for &p in v {
                assert!(seen.insert(p));
            }
        }
    }

    #[test]
    fn close_pairs_are_in_neighbor_cells() {
        // The conservative-candidates guarantee: any pair within eps (full
        // distance) lands in cells within Chebyshev distance 1.
        let m = Matrix::random(300, 3, 11, 0.0, 5.0);
        let eps = 0.5f32;
        let g = GridIndex::build(&m, eps);
        let cell_of = |p: usize| -> Cell {
            let c0 = ((m.at(p, 0) - g.origin.0) / eps).floor() as u32;
            let c1 = ((m.at(p, 1) - g.origin.1) / eps).floor() as u32;
            (c0, c1)
        };
        for a in 0..300 {
            for b in (a + 1)..300 {
                let d: f32 = (0..3).map(|k| (m.at(a, k) - m.at(b, k)).powi(2)).sum::<f32>().sqrt();
                if d <= eps {
                    assert!(
                        GridIndex::neighbors(cell_of(a), cell_of(b)),
                        "close pair ({a},{b}) in non-neighbor cells"
                    );
                }
            }
        }
    }

    #[test]
    fn empty_input() {
        let m = Matrix::zeros(0, 2);
        let g = GridIndex::build(&m, 1.0);
        assert!(g.is_empty());
        assert_eq!(g.mean_occupancy(), 0.0);
    }

    #[test]
    fn hilbert_ranks_are_inverse_permutations() {
        let m = Matrix::random(200, 2, 5, 0.0, 8.0);
        let g = GridIndex::build(&m, 0.9);
        let (order, rank) = g.hilbert_cell_ranks();
        assert_eq!(order.len(), g.len());
        assert_eq!(rank.len(), g.len());
        for (pos, &idx) in order.iter().enumerate() {
            assert_eq!(rank[idx as usize] as usize, pos);
        }
        // Hilbert order: strictly increasing order values along `order`.
        use crate::curves::hilbert::Hilbert;
        use crate::curves::SpaceFillingCurve;
        let cells = g.cells();
        for w in order.windows(2) {
            let a = cells[w[0] as usize].0;
            let b = cells[w[1] as usize].0;
            assert!(Hilbert::order(a.0, a.1) < Hilbert::order(b.0, b.1));
        }
    }

    #[test]
    fn neighbors_relation() {
        assert!(GridIndex::neighbors((3, 3), (4, 2)));
        assert!(GridIndex::neighbors((3, 3), (3, 3)));
        assert!(!GridIndex::neighbors((3, 3), (5, 3)));
    }
}
