//! Order-sorted space-filling-curve index — the paper's first-listed
//! application (search structures), as a queryable structure.
//!
//! [`SfcIndex`] quantizes each point onto a `side^d` grid, permutes the
//! rows into their d-dimensional curve order
//! ([`sfc_argsort`](crate::curves::ndim::sfc_argsort), Hilbert by
//! default) and keeps the curve keys in a sorted column. Queries then
//! work on contiguous memory:
//!
//! * [`SfcIndex::query_window`] — decompose the window into contiguous
//!   key ranges ([`CurveMapperNd::decompose_nd`]), binary-search each
//!   range, exact-filter the candidates against the float window. The
//!   clustering property governs the cost: the better the curve keeps
//!   neighborhoods contiguous, the fewer ranges (and seeks) per window —
//!   fewest for Hilbert.
//! * [`SfcIndex::query_point`] — one key lookup plus an equality filter.
//! * [`SfcIndex::query_knn`] — expanding-window search with a bounded
//!   max-heap: grow a centered window until the k-th best distance is
//!   covered by the window radius (an L∞ window of radius `r` contains
//!   every point within Euclidean distance `r`).
//!
//! Coarsening ([`coarsen_ranges`]) trades false-positive candidates for
//! fewer ranges via the `max_ranges` knob on
//! [`SfcIndex::query_window_stats`].

use crate::apps::Matrix;
use crate::curves::engine::{coarsen_ranges, CurveMapperNd, DomainNd, WindowNd};
use crate::curves::ndim::argsort_stable;
use crate::curves::CurveKind;
use std::collections::BinaryHeap;

/// Statistics of one window query.
#[derive(Copy, Clone, Debug, Default)]
pub struct QueryStats {
    /// Contiguous key ranges after decomposition (and coarsening).
    pub ranges: usize,
    /// Candidate points scanned across all ranges.
    pub candidates: u64,
    /// Points surviving the exact float filter.
    pub results: u64,
}

impl QueryStats {
    /// Fraction of candidates surviving the exact filter (1.0 when the
    /// decomposition produced no false positives).
    pub fn filter_ratio(&self) -> f64 {
        if self.candidates == 0 {
            1.0
        } else {
            self.results as f64 / self.candidates as f64
        }
    }
}

/// A k-nearest-neighbor candidate in the query's max-heap (ordered by
/// distance, ties by id, via total order on the floats).
#[derive(Copy, Clone, Debug)]
struct Neighbor {
    dist: f32,
    id: u32,
}

impl PartialEq for Neighbor {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == std::cmp::Ordering::Equal
    }
}

impl Eq for Neighbor {}

impl PartialOrd for Neighbor {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Neighbor {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.dist
            .total_cmp(&other.dist)
            .then(self.id.cmp(&other.id))
    }
}

/// Order-sorted curve index over an `n×d` point set.
pub struct SfcIndex {
    kind: CurveKind,
    level: u32,
    dims: usize,
    /// Quantization cells per axis (the curve cube's side).
    side: u32,
    /// Per-axis minimum of the data (the quantization origin).
    origin: Vec<f32>,
    /// Per-axis quantization cell width (`0` for degenerate axes).
    cell: Vec<f32>,
    /// The d-dim curve the keys live on.
    mapper: Box<dyn CurveMapperNd>,
    /// Sorted curve keys, one per point (the search column).
    keys: Vec<u64>,
    /// Key position → original row id (the curve-order permutation).
    ids: Vec<u32>,
    /// Point rows permuted into curve order (candidate scans read
    /// contiguous memory).
    points: Matrix,
}

impl SfcIndex {
    /// Build a d-dimensional **Hilbert** index over all columns of
    /// `points` at `2^level` quantization cells per axis.
    pub fn build(points: &Matrix, level: u32) -> Self {
        Self::build_with(points, level, CurveKind::Hilbert)
    }

    /// [`SfcIndex::build`] with an explicit curve (Z-order and canonic
    /// are the measured baselines; Hilbert wins on ranges-per-window).
    pub fn build_with(points: &Matrix, level: u32, kind: CurveKind) -> Self {
        let dims = points.cols;
        assert!(dims >= 1, "points must have at least one column");
        assert!(
            dims <= if kind == CurveKind::Peano { 13 } else { 16 },
            "dims {dims} exceeds the curve's supported dimensionality"
        );
        // Clamp the refinement so the order span fits u64 (the same caps
        // the Nd mappers enforce).
        let max_level = match kind {
            CurveKind::Peano => (39 / dims as u32).min(20),
            _ => (63 / dims as u32).min(31),
        };
        let level = level.clamp(1, max_level.max(1));
        let mapper = kind.nd_mapper(dims, level);
        let side = match mapper.domain_nd() {
            DomainNd::HyperRect { shape } => shape[0],
            _ => unreachable!("nd_mapper domains are hyperrects"),
        };
        let (origin, cell) = match super::axis_bounds(points, dims) {
            Some((min, max)) => {
                let cell = (0..dims)
                    .map(|a| (max[a] - min[a]) / side as f32)
                    .collect();
                (min, cell)
            }
            None => (vec![0.0; dims], vec![0.0; dims]),
        };
        let mut index = SfcIndex {
            kind,
            level,
            dims,
            side,
            origin,
            cell,
            mapper,
            keys: Vec::new(),
            ids: Vec::new(),
            points: Matrix::zeros(0, dims),
        };
        if points.rows == 0 {
            return index;
        }
        // Quantize every row, convert through the batched Nd path, and
        // permute rows into curve order (stable argsort keeps ties in
        // input order).
        let mut flat = Vec::with_capacity(points.rows * dims);
        for p in 0..points.rows {
            for (a, &v) in points.row(p).iter().enumerate() {
                flat.push(index.cell_of(v, a));
            }
        }
        let mut keys = Vec::with_capacity(points.rows);
        index.mapper.order_batch_nd(&flat, &mut keys);
        let order = argsort_stable(&keys);
        index.keys = order.iter().map(|&idx| keys[idx as usize]).collect();
        index.points = Matrix::from_fn(points.rows, dims, |p, a| {
            points.at(order[p] as usize, a)
        });
        index.ids = order;
        index
    }

    /// Number of indexed points.
    pub fn len(&self) -> usize {
        self.keys.len()
    }

    /// True when the index holds no points.
    pub fn is_empty(&self) -> bool {
        self.keys.is_empty()
    }

    /// The curve the keys live on.
    pub fn curve(&self) -> CurveKind {
        self.kind
    }

    /// Quantization level actually used (may be clamped below the
    /// requested one so the order span fits `u64`).
    pub fn level(&self) -> u32 {
        self.level
    }

    /// Indexed dimensions (all point columns).
    pub fn dims(&self) -> usize {
        self.dims
    }

    /// Quantized cell coordinate of value `v` on axis `a` (monotone in
    /// `v` and clamped to the grid, which is what keeps window
    /// decomposition conservative: a point inside a float window always
    /// lands inside the quantized window).
    #[inline]
    fn cell_of(&self, v: f32, a: usize) -> u32 {
        let c = self.cell[a];
        if c <= 0.0 {
            return 0;
        }
        let q = ((v - self.origin[a]) / c).floor();
        if q < 0.0 {
            0
        } else if q >= self.side as f32 {
            self.side - 1
        } else {
            q as u32
        }
    }

    /// First key position with `keys[pos] >= key`.
    #[inline]
    fn lower_bound(&self, key: u64) -> usize {
        self.keys.partition_point(|&k| k < key)
    }

    /// All points exactly equal to `q` (`q.len() == dims`): one key
    /// lookup on the quantized cell plus an equality filter over the
    /// (contiguous) key run.
    pub fn query_point(&self, q: &[f32]) -> Vec<u32> {
        assert_eq!(q.len(), self.dims, "query dims must match the index");
        if self.is_empty() {
            return Vec::new();
        }
        let cell: Vec<u32> = q.iter().enumerate().map(|(a, &v)| self.cell_of(v, a)).collect();
        let key = self.mapper.order_nd(&cell);
        let mut out = Vec::new();
        let mut pos = self.lower_bound(key);
        while pos < self.keys.len() && self.keys[pos] == key {
            if self.points.row(pos).iter().zip(q).all(|(&a, &b)| a == b) {
                out.push(self.ids[pos]);
            }
            pos += 1;
        }
        out
    }

    /// Ids of all points inside the closed float window `[lo, hi]`.
    pub fn query_window(&self, lo: &[f32], hi: &[f32]) -> Vec<u32> {
        self.query_window_stats(lo, hi, 0).0
    }

    /// [`SfcIndex::query_window`] with query statistics and a
    /// `max_ranges` coarsening cap (`0` = exact decomposition): merging
    /// nearest ranges trades false-positive candidates for fewer binary
    /// searches, never losing a true hit.
    pub fn query_window_stats(
        &self,
        lo: &[f32],
        hi: &[f32],
        max_ranges: usize,
    ) -> (Vec<u32>, QueryStats) {
        let (positions, stats) = self.window_positions(lo, hi, max_ranges);
        (positions.into_iter().map(|pos| self.ids[pos]).collect(), stats)
    }

    /// Shared window-query core: sorted key positions (not ids) of the
    /// exact hits, so callers that need the permuted rows (kNN) skip the
    /// id indirection.
    fn window_positions(
        &self,
        lo: &[f32],
        hi: &[f32],
        max_ranges: usize,
    ) -> (Vec<usize>, QueryStats) {
        assert_eq!(lo.len(), self.dims, "query dims must match the index");
        assert_eq!(hi.len(), self.dims, "query dims must match the index");
        assert!(
            lo.iter().zip(hi).all(|(a, b)| a <= b),
            "window lo must be ≤ hi per axis"
        );
        let mut stats = QueryStats::default();
        let mut out = Vec::new();
        if self.is_empty() {
            return (out, stats);
        }
        let clo: Vec<u32> = lo.iter().enumerate().map(|(a, &v)| self.cell_of(v, a)).collect();
        let chi: Vec<u32> = hi.iter().enumerate().map(|(a, &v)| self.cell_of(v, a)).collect();
        let mut ranges = self.mapper.decompose_nd(&WindowNd::new(clo, chi));
        coarsen_ranges(&mut ranges, max_ranges);
        stats.ranges = ranges.len();
        for r in &ranges {
            let mut pos = self.lower_bound(r.start);
            while pos < self.keys.len() && self.keys[pos] < r.end {
                stats.candidates += 1;
                let row = self.points.row(pos);
                if row
                    .iter()
                    .zip(lo.iter().zip(hi))
                    .all(|(&v, (&l, &h))| (l..=h).contains(&v))
                {
                    out.push(pos);
                    stats.results += 1;
                }
                pos += 1;
            }
        }
        (out, stats)
    }

    /// The `k` nearest neighbors of `q` by Euclidean distance, sorted
    /// ascending as `(id, distance)` (fewer than `k` when the index is
    /// smaller). Expanding-window search: a centered L∞ window of radius
    /// `r` is complete for any answer distance `≤ r`, so the window
    /// doubles until the heap's k-th distance is covered (or the data's
    /// bounding box is).
    pub fn query_knn(&self, q: &[f32], k: usize) -> Vec<(u32, f32)> {
        assert_eq!(q.len(), self.dims, "query dims must match the index");
        if self.is_empty() || k == 0 {
            return Vec::new();
        }
        // Start at one quantization cell; degenerate (single-cell) data
        // still needs a positive radius to make progress.
        let mut r = self.cell.iter().cloned().fold(0.0f32, f32::max);
        if r <= 0.0 {
            r = 1e-6;
        }
        let mut lo = vec![0.0f32; self.dims];
        let mut hi = vec![0.0f32; self.dims];
        loop {
            for a in 0..self.dims {
                lo[a] = q[a] - r;
                hi[a] = q[a] + r;
            }
            let mut heap: BinaryHeap<Neighbor> = BinaryHeap::with_capacity(k + 1);
            for pos in self.window_positions(&lo, &hi, 0).0 {
                let row = self.points.row(pos);
                let dist2: f32 = row.iter().zip(q).map(|(&a, &b)| (a - b) * (a - b)).sum();
                heap.push(Neighbor { dist: dist2.sqrt(), id: self.ids[pos] });
                if heap.len() > k {
                    heap.pop();
                }
            }
            let covers = (0..self.dims).all(|a| {
                lo[a] <= self.origin[a]
                    && hi[a] >= self.origin[a] + self.cell[a] * self.side as f32
            });
            let done = heap.len() == k && heap.peek().map(|n| n.dist <= r).unwrap_or(false);
            if covers || done {
                let mut best = heap.into_vec();
                best.sort();
                return best.into_iter().map(|n| (n.id, n.dist)).collect();
            }
            r *= 2.0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn brute_window(points: &Matrix, lo: &[f32], hi: &[f32]) -> Vec<u32> {
        (0..points.rows as u32)
            .filter(|&p| {
                points
                    .row(p as usize)
                    .iter()
                    .zip(lo.iter().zip(hi))
                    .all(|(&v, (&l, &h))| (l..=h).contains(&v))
            })
            .collect()
    }

    fn sorted(mut v: Vec<u32>) -> Vec<u32> {
        v.sort_unstable();
        v
    }

    #[test]
    fn window_matches_brute_force() {
        let points = Matrix::random(500, 3, 11, 0.0, 100.0);
        let index = SfcIndex::build(&points, 6);
        let mut rng = Rng::new(5);
        for _ in 0..50 {
            let lo: Vec<f32> = (0..3).map(|_| rng.f32() * 90.0).collect();
            let hi: Vec<f32> = lo.iter().map(|&l| l + rng.f32() * 30.0).collect();
            let got = index.query_window(&lo, &hi);
            assert_eq!(sorted(got), sorted(brute_window(&points, &lo, &hi)));
        }
    }

    #[test]
    fn window_matches_brute_force_for_every_curve() {
        let points = Matrix::random(300, 2, 3, -5.0, 5.0);
        for kind in CurveKind::ALL {
            let index = SfcIndex::build_with(&points, 5, kind);
            let mut rng = Rng::new(7);
            for _ in 0..25 {
                let lo: Vec<f32> = (0..2).map(|_| rng.f32() * 8.0 - 5.0).collect();
                let hi: Vec<f32> = lo.iter().map(|&l| l + rng.f32() * 4.0).collect();
                let got = index.query_window(&lo, &hi);
                assert_eq!(
                    sorted(got),
                    sorted(brute_window(&points, &lo, &hi)),
                    "{}",
                    kind.name()
                );
            }
        }
    }

    #[test]
    fn coarsening_never_loses_hits() {
        let points = Matrix::random(400, 2, 13, 0.0, 50.0);
        let index = SfcIndex::build(&points, 7);
        let mut rng = Rng::new(17);
        for _ in 0..30 {
            let lo: Vec<f32> = (0..2).map(|_| rng.f32() * 40.0).collect();
            let hi: Vec<f32> = lo.iter().map(|&l| l + rng.f32() * 15.0).collect();
            let (exact, se) = index.query_window_stats(&lo, &hi, 0);
            for cap in [1usize, 2, 4, 8] {
                let (coarse, sc) = index.query_window_stats(&lo, &hi, cap);
                assert_eq!(sorted(exact.clone()), sorted(coarse), "cap={cap}");
                assert!(sc.ranges <= cap.max(1));
                assert!(sc.candidates >= se.candidates);
            }
        }
    }

    #[test]
    fn point_query_finds_exact_rows() {
        let points = Matrix::random(200, 4, 23, 0.0, 10.0);
        let index = SfcIndex::build(&points, 5);
        for p in [0usize, 17, 99, 199] {
            let q: Vec<f32> = points.row(p).to_vec();
            let got = index.query_point(&q);
            assert!(got.contains(&(p as u32)), "row {p} not found");
            for &id in &got {
                assert_eq!(points.row(id as usize), &q[..]);
            }
        }
        assert!(index.query_point(&[1e9, 1e9, 1e9, 1e9]).is_empty());
    }

    #[test]
    fn knn_matches_brute_force() {
        let points = Matrix::random(300, 3, 29, 0.0, 20.0);
        let index = SfcIndex::build(&points, 5);
        let mut rng = Rng::new(31);
        for _ in 0..20 {
            let q: Vec<f32> = (0..3).map(|_| rng.f32() * 30.0 - 5.0).collect();
            let k = 1 + rng.below(10) as usize;
            let got = index.query_knn(&q, k);
            let mut brute: Vec<(u32, f32)> = (0..points.rows as u32)
                .map(|p| {
                    let d2: f32 = points
                        .row(p as usize)
                        .iter()
                        .zip(&q)
                        .map(|(&a, &b)| (a - b) * (a - b))
                        .sum();
                    (p, d2.sqrt())
                })
                .collect();
            brute.sort_by(|a, b| a.1.total_cmp(&b.1).then(a.0.cmp(&b.0)));
            assert_eq!(got.len(), k);
            for (g, w) in got.iter().zip(&brute) {
                assert!((g.1 - w.1).abs() < 1e-5, "distance mismatch {g:?} vs {w:?}");
            }
        }
    }

    #[test]
    fn empty_and_degenerate_inputs() {
        let empty = Matrix::zeros(0, 3);
        let index = SfcIndex::build(&empty, 6);
        assert!(index.is_empty());
        assert!(index.query_window(&[0.0; 3], &[1.0; 3]).is_empty());
        assert!(index.query_knn(&[0.0; 3], 3).is_empty());
        // All points identical: every query degenerates to cell 0.
        let same = Matrix::from_fn(10, 2, |_, _| 4.2);
        let index = SfcIndex::build(&same, 6);
        assert_eq!(index.query_window(&[4.0, 4.0], &[5.0, 5.0]).len(), 10);
        assert_eq!(index.query_point(&[4.2, 4.2]).len(), 10);
        assert_eq!(index.query_knn(&[0.0, 0.0], 3).len(), 3);
    }

    #[test]
    fn knn_with_k_larger_than_index() {
        let points = Matrix::random(5, 2, 41, 0.0, 1.0);
        let index = SfcIndex::build(&points, 4);
        let got = index.query_knn(&[0.5, 0.5], 20);
        assert_eq!(got.len(), 5);
    }

    #[test]
    fn level_is_clamped_to_u64_span() {
        let points = Matrix::random(50, 8, 43, 0.0, 1.0);
        let index = SfcIndex::build(&points, 31);
        assert!(index.level() * 8 <= 63);
        assert!(!index.query_window(&[0.0; 8], &[1.0; 8]).is_empty());
    }
}
