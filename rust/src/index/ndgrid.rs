//! d-dimensional uniform grid index over a point set.
//!
//! The full-dimensional similarity-join substrate: points are bucketed
//! into hypercubic cells of side `eps` over the first `dims` dimensions.
//! Any join pair within distance `eps` in the full space lies in cells
//! within Chebyshev distance 1 **in every indexed dimension**, so the
//! candidate set tightens with each dimension indexed — unlike the 2-D
//! [`GridIndex`](super::GridIndex), which projects onto dims 0–1 and lets
//! points that are far apart in the remaining dimensions share cells,
//! inflating join candidate sets for d ≥ 3.
//!
//! [`GridIndexNd::hilbert_cell_ranks`] numbers the non-empty cells along
//! their **d-dimensional** Hilbert order through the engine's Nd batched
//! conversion ([`crate::curves::ndim::HilbertNd`]), which is what
//! transfers true d-dim curve
//! locality onto index-driven workloads (the similarity join's cell-pair
//! grid, k-means sharding).

use crate::apps::Matrix;
use crate::curves::ndim::hilbert_argsort;

/// A d-dimensional grid cell coordinate (0-based after offsetting).
pub type CellNd = Vec<u32>;

/// d-dimensional uniform grid index.
#[derive(Clone, Debug)]
pub struct GridIndexNd {
    /// Cell side length (= join radius).
    pub eps: f32,
    /// Number of indexed dimensions (a prefix of the point dimensions).
    pub dims: usize,
    /// Minimum corner of the bounding box over the indexed dimensions.
    pub origin: Vec<f32>,
    /// Grid extent in cells per indexed axis.
    pub extent: Vec<u32>,
    /// Non-empty cells with their point lists, sorted by cell coordinate
    /// (lexicographic).
    cells: Vec<(CellNd, Vec<u32>)>,
}

impl GridIndexNd {
    /// Build the index for join radius `eps` (> 0) over all dimensions of
    /// `points`.
    pub fn build(points: &Matrix, eps: f32) -> Self {
        Self::build_dims(points, eps, points.cols)
    }

    /// Build the index over the first `dims` dimensions only
    /// (`1 ≤ dims ≤ points.cols`). Projecting onto a dimension prefix
    /// keeps the candidate set conservative (no false dismissals) while
    /// bounding the `3^dims` neighbor enumeration of the join drivers.
    /// The min/max scan and cell bucketing are the shared
    /// [`axis_bounds`](super::axis_bounds) / [`bucket_cells`](super::bucket_cells)
    /// machinery.
    pub fn build_dims(points: &Matrix, eps: f32, dims: usize) -> Self {
        assert!(eps > 0.0, "eps must be positive");
        let (origin, maxv) = match super::axis_bounds(points, dims) {
            Some(b) => b,
            None => {
                return GridIndexNd {
                    eps,
                    dims,
                    origin: vec![0.0; dims],
                    extent: vec![0; dims],
                    cells: Vec::new(),
                }
            }
        };
        let extent: Vec<u32> = (0..dims)
            .map(|a| ((maxv[a] - origin[a]) / eps).floor() as u32 + 1)
            .collect();
        let cells = super::bucket_cells(points, eps, &origin, dims);
        GridIndexNd { eps, dims, origin, extent, cells }
    }

    /// Number of non-empty cells.
    pub fn len(&self) -> usize {
        self.cells.len()
    }

    /// True if the index holds no points.
    pub fn is_empty(&self) -> bool {
        self.cells.is_empty()
    }

    /// Non-empty cells, sorted by coordinate.
    pub fn cells(&self) -> &[(CellNd, Vec<u32>)] {
        &self.cells
    }

    /// Points of the cell at `coord`, if non-empty.
    pub fn cell_points(&self, coord: &[u32]) -> Option<&[u32]> {
        self.cells
            .binary_search_by(|(c, _)| c.as_slice().cmp(coord))
            .ok()
            .map(|idx| self.cells[idx].1.as_slice())
    }

    /// Are two cells within Chebyshev distance 1 in every dimension
    /// (i.e. a candidate pair)?
    pub fn neighbors(a: &[u32], b: &[u32]) -> bool {
        a.iter().zip(b).all(|(&x, &y)| x.abs_diff(y) <= 1)
    }

    /// Number the non-empty cells along their spatial **d-dimensional**
    /// Hilbert order.
    ///
    /// Returns `(order, rank)`: `order[pos]` is the cells-index of the
    /// `pos`-th cell in Hilbert order, and `rank[idx]` is the Hilbert
    /// position of cells-index `idx` (mutually inverse permutations).
    /// Cell coordinates convert through the engine's Nd batched path
    /// ([`crate::curves::ndim::hilbert_argsort`]), amortising the
    /// automaton across the whole index.
    ///
    /// The curve runs over the first `min(dims, 16)` axes at a level
    /// capped so `dims·level ≤ 63`; oversized extents are quantized to
    /// the coarser cube (ties keep the coordinate sort order, which the
    /// stable sort preserves).
    pub fn hilbert_cell_ranks(&self) -> (Vec<u32>, Vec<u32>) {
        if self.cells.is_empty() {
            return (Vec::new(), Vec::new());
        }
        let cd = self.dims.min(16);
        let maxc = self
            .cells
            .iter()
            .flat_map(|(c, _)| c[..cd].iter().copied())
            .max()
            .unwrap_or(0);
        let needed = (32 - maxc.leading_zeros()).max(1);
        let allowed = (63 / cd as u32).clamp(1, 31);
        let level = needed.min(allowed);
        let shift = needed - level;
        let mut flat = Vec::with_capacity(self.cells.len() * cd);
        for (c, _) in &self.cells {
            for &v in &c[..cd] {
                flat.push(v >> shift);
            }
        }
        let order = hilbert_argsort(&flat, cd, level);
        let mut rank = vec![0u32; self.cells.len()];
        for (pos, &idx) in order.iter().enumerate() {
            rank[idx as usize] = pos as u32;
        }
        (order, rank)
    }

    /// Average points per non-empty cell.
    pub fn mean_occupancy(&self) -> f64 {
        if self.cells.is_empty() {
            0.0
        } else {
            self.cells.iter().map(|(_, v)| v.len() as f64).sum::<f64>() / self.cells.len() as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_points_correctly_in_3d() {
        let m = Matrix::from_fn(4, 3, |i, j| {
            [[0.1, 0.1, 0.1], [0.2, 0.15, 0.3], [2.5, 0.1, 0.1], [0.1, 0.1, 2.5]][i][j]
        });
        let g = GridIndexNd::build(&m, 1.0);
        assert_eq!(g.dims, 3);
        assert_eq!(g.len(), 3);
        assert_eq!(g.cell_points(&[0, 0, 0]).unwrap(), &[0, 1]);
        assert_eq!(g.cell_points(&[2, 0, 0]).unwrap(), &[2]);
        assert_eq!(g.cell_points(&[0, 0, 2]).unwrap(), &[3]);
        assert_eq!(g.extent, vec![3, 1, 3]);
    }

    #[test]
    fn every_point_in_exactly_one_cell() {
        let m = Matrix::random(500, 5, 3, -10.0, 10.0);
        let g = GridIndexNd::build(&m, 0.7);
        let total: usize = g.cells().iter().map(|(_, v)| v.len()).sum();
        assert_eq!(total, 500);
        let mut seen = std::collections::HashSet::new();
        for (_, v) in g.cells() {
            for &p in v {
                assert!(seen.insert(p));
            }
        }
    }

    #[test]
    fn close_pairs_are_in_neighbor_cells_full_dim() {
        let m = Matrix::random(300, 3, 11, 0.0, 5.0);
        let eps = 0.5f32;
        let g = GridIndexNd::build(&m, eps);
        let cell_of = |p: usize| -> Vec<u32> {
            (0..3)
                .map(|a| ((m.at(p, a) - g.origin[a]) / eps).floor() as u32)
                .collect()
        };
        for a in 0..300 {
            for b in (a + 1)..300 {
                let d: f32 = (0..3)
                    .map(|k| (m.at(a, k) - m.at(b, k)).powi(2))
                    .sum::<f32>()
                    .sqrt();
                if d <= eps {
                    assert!(
                        GridIndexNd::neighbors(&cell_of(a), &cell_of(b)),
                        "close pair ({a},{b}) in non-neighbor cells"
                    );
                }
            }
        }
    }

    #[test]
    fn dims_prefix_matches_2d_index() {
        // A 2-dim prefix index buckets exactly like the legacy GridIndex.
        use crate::index::GridIndex;
        let m = Matrix::random(200, 4, 9, 0.0, 8.0);
        let g2 = GridIndex::build(&m, 0.9);
        let gn = GridIndexNd::build_dims(&m, 0.9, 2);
        assert_eq!(g2.len(), gn.len());
        for ((c2, pts2), (cn, ptsn)) in g2.cells().iter().zip(gn.cells()) {
            assert_eq!(vec![c2.0, c2.1], *cn);
            assert_eq!(pts2, ptsn);
        }
    }

    #[test]
    fn empty_input() {
        let m = Matrix::zeros(0, 3);
        let g = GridIndexNd::build(&m, 1.0);
        assert!(g.is_empty());
        assert_eq!(g.mean_occupancy(), 0.0);
        assert_eq!(g.hilbert_cell_ranks(), (Vec::new(), Vec::new()));
    }

    #[test]
    fn hilbert_ranks_are_inverse_permutations_3d() {
        let m = Matrix::random(300, 3, 5, 0.0, 8.0);
        let g = GridIndexNd::build(&m, 0.9);
        let (order, rank) = g.hilbert_cell_ranks();
        assert_eq!(order.len(), g.len());
        assert_eq!(rank.len(), g.len());
        for (pos, &idx) in order.iter().enumerate() {
            assert_eq!(rank[idx as usize] as usize, pos);
        }
        // d-dim Hilbert order: non-decreasing order values along `order`
        // (strict when no quantization collapses cells; extents here are
        // small, so no clamping and the values are strictly increasing).
        let maxc = g
            .cells()
            .iter()
            .flat_map(|(c, _)| c.iter().copied())
            .max()
            .unwrap();
        let level = (32 - maxc.leading_zeros()).max(1);
        use crate::curves::engine::CurveMapperNd;
        use crate::curves::ndim::HilbertNd;
        let h = HilbertNd::new(3, level);
        for w in order.windows(2) {
            let a = &g.cells()[w[0] as usize].0;
            let b = &g.cells()[w[1] as usize].0;
            assert!(h.order_nd(a) < h.order_nd(b));
        }
    }

    #[test]
    fn neighbors_relation() {
        assert!(GridIndexNd::neighbors(&[3, 3, 3], &[4, 2, 3]));
        assert!(GridIndexNd::neighbors(&[3, 3, 3], &[3, 3, 3]));
        assert!(!GridIndexNd::neighbors(&[3, 3, 3], &[5, 3, 3]));
        assert!(!GridIndexNd::neighbors(&[3, 3, 0], &[3, 3, 2]));
    }
}
