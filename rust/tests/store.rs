//! Store test suite (ISSUE 5): parity with a freshly rebuilt `SfcIndex`
//! after any tested interleaving of inserts, deletes, compactions and
//! rebalances — for every `CurveKind` at d ∈ {2, 3} — plus snapshot
//! isolation and a threaded mixed-workload stress test.

use sfc_mine::apps::Matrix;
use sfc_mine::coordinator::Coordinator;
use sfc_mine::curves::CurveKind;
use sfc_mine::index::{SfcIndex, SfcStore, StoreConfig};
use sfc_mine::util::rng::Rng;
use std::collections::BTreeMap;

/// Ground truth: id → row.
type Alive = BTreeMap<u32, Vec<f32>>;

fn live_matrix(alive: &Alive, d: usize) -> (Vec<u32>, Matrix) {
    let ids: Vec<u32> = alive.keys().copied().collect();
    let rows = Matrix::from_fn(ids.len(), d, |i, j| alive[&ids[i]][j]);
    (ids, rows)
}

/// Assert all three query faces of `store` equal a fresh `SfcIndex`
/// over the live set (window/point by id set, kNN by bitwise distance).
fn assert_parity(
    store: &SfcStore,
    alive: &Alive,
    d: usize,
    level: u32,
    kind: CurveKind,
    rng: &mut Rng,
    ctx: &str,
) {
    let (ids, rows) = live_matrix(alive, d);
    let index = SfcIndex::build_with(&rows, level, kind);
    let snap = store.snapshot();
    // The store's live set must be exactly the ground truth (bitwise).
    let (sids, srows) = store.collect_live(&snap);
    assert_eq!(sids.len(), ids.len(), "{ctx}: live count");
    for (pos, &id) in sids.iter().enumerate() {
        assert_eq!(
            srows.row(pos),
            &alive[&id][..],
            "{ctx}: live row of id {id} diverged"
        );
    }
    // Window parity.
    for _ in 0..6 {
        let lo: Vec<f32> = (0..d).map(|_| rng.f32() * 80.0).collect();
        let hi: Vec<f32> = lo.iter().map(|&l| l + rng.f32() * 30.0).collect();
        let mut got = store.query_window_on(&snap, &lo, &hi);
        let mut want: Vec<u32> = index
            .query_window(&lo, &hi)
            .iter()
            .map(|&i| ids[i as usize])
            .collect();
        got.sort_unstable();
        want.sort_unstable();
        assert_eq!(got, want, "{ctx}: window parity");
        // Parallel per-shard fan-out returns the same rows.
        let coord = Coordinator::new(3);
        let (mut par, stats) = store.par_query_window(&coord, &lo, &hi, 0);
        par.sort_unstable();
        assert_eq!(par, want, "{ctx}: par_query_window parity");
        assert!(stats.shards_touched >= 1 || want.is_empty());
        assert!(!stats.filter_ratio().is_nan());
    }
    // Point parity (an existing row and a missing one).
    if let Some((&id, row)) = alive.iter().next() {
        let got = store.query_point_on(&snap, row);
        assert!(got.contains(&id), "{ctx}: point query lost id {id}");
        let want: Vec<u32> = index
            .query_point(row)
            .iter()
            .map(|&i| ids[i as usize])
            .collect();
        let mut got = got;
        let mut want = want;
        got.sort_unstable();
        want.sort_unstable();
        assert_eq!(got, want, "{ctx}: point parity");
    }
    assert!(store.query_point_on(&snap, &vec![1e9; d]).is_empty());
    // kNN parity: identical distance sequences, bit for bit (both sides
    // run the same expanding-window driver and float arithmetic).
    if !alive.is_empty() {
        let q: Vec<f32> = (0..d).map(|_| rng.f32() * 100.0).collect();
        let k = 1 + rng.below(8) as usize;
        let got = store.query_knn_on(&snap, &q, k);
        let want = index.query_knn(&q, k);
        assert_eq!(got.len(), want.len(), "{ctx}: knn count");
        for (g, w) in got.iter().zip(&want) {
            assert_eq!(
                g.1.to_bits(),
                w.1.to_bits(),
                "{ctx}: knn distance diverged ({} vs {})",
                g.1,
                w.1
            );
        }
    }
}

/// The acceptance property: scripted interleavings of inserts, deletes,
/// flushes, compactions and rebalances keep every query face equal to a
/// from-scratch `SfcIndex` on the live set — for every curve at
/// d ∈ {2, 3}.
#[test]
fn store_matches_fresh_index_after_interleaved_mutations() {
    for kind in CurveKind::ALL {
        for d in [2usize, 3] {
            let level = 6u32;
            // Tiny buffer so the script exercises flush + tier merges.
            let store = SfcStore::new(
                d,
                level,
                kind,
                vec![0.0; d],
                &vec![100.0; d],
                StoreConfig { shards: 4, buffer_rows: 32 },
            );
            let mut alive: Alive = Alive::new();
            let mut rng = Rng::new(1000 + d as u64);
            for step in 0..8 {
                // A batch of inserts…
                let n = 20 + rng.below(30) as usize;
                let rows = Matrix::from_fn(n, d, |_, _| rng.f32() * 100.0);
                let first = store.insert_batch(&rows);
                for i in 0..n {
                    alive.insert(first + i as u32, rows.row(i).to_vec());
                }
                // …some deletes…
                let del = rng.below(10) as usize;
                for _ in 0..del {
                    if let Some((&id, row)) = alive.iter().next() {
                        let row = row.clone();
                        store.delete(id, &row);
                        alive.remove(&id);
                    }
                }
                // …and periodic structural maintenance.
                match step % 4 {
                    1 => store.flush(),
                    2 => store.compact(),
                    3 => store.rebalance(),
                    _ => {}
                }
                assert_parity(
                    &store,
                    &alive,
                    d,
                    level,
                    kind,
                    &mut rng,
                    &format!("{} d={d} step={step}", kind.name()),
                );
            }
        }
    }
}

/// Deleting and re-inserting under fresh ids (the store model) keeps
/// point queries exact even when old versions share the curve key.
#[test]
fn reinsert_after_delete_resolves_to_newest() {
    let store = SfcStore::new(
        2,
        6,
        CurveKind::Hilbert,
        vec![0.0, 0.0],
        &[10.0, 10.0],
        StoreConfig { shards: 2, buffer_rows: 8 },
    );
    let a = store.insert(&[3.0, 4.0]);
    store.delete(a, &[3.0, 4.0]);
    let b = store.insert(&[3.0, 4.0]);
    assert_eq!(store.query_point(&[3.0, 4.0]), vec![b]);
    store.compact();
    assert_eq!(store.query_point(&[3.0, 4.0]), vec![b]);
    assert_eq!(store.len(), 1);
    // Forcing tombstones through the tier pipeline keeps the result.
    for i in 0..40u32 {
        let id = store.insert(&[i as f32 * 0.2, 1.0]);
        if i % 2 == 0 {
            store.delete(id, &[i as f32 * 0.2, 1.0]);
        }
    }
    assert_eq!(store.len(), 21);
    assert_eq!(store.query_point(&[3.0, 4.0]), vec![b]);
}

/// Snapshot isolation: a query started before a batch of inserts (or a
/// delete, or a compaction) never sees them.
#[test]
fn snapshots_isolate_from_later_mutations() {
    let points = Matrix::random(300, 2, 5, 0.0, 50.0);
    let store = SfcStore::from_points(&points, 6, CurveKind::Hilbert, StoreConfig::default());
    let before = store.snapshot();
    let window = (vec![0.0f32, 0.0], vec![50.0f32, 50.0]);
    let seen_before = store.query_window_on(&before, &window.0, &window.1);
    assert_eq!(seen_before.len(), 300);

    // Insert a batch: old snapshot unchanged, store sees it.
    let extra = Matrix::random(50, 2, 7, 0.0, 50.0);
    store.insert_batch(&extra);
    assert_eq!(store.query_window_on(&before, &window.0, &window.1).len(), 300);
    assert_eq!(store.query_window(&window.0, &window.1).len(), 350);

    // Delete: old snapshots still see the victim.
    let mid = store.snapshot();
    store.delete(0, points.row(0));
    assert_eq!(store.query_window_on(&before, &window.0, &window.1).len(), 300);
    assert_eq!(store.query_window_on(&mid, &window.0, &window.1).len(), 350);
    assert_eq!(store.query_window(&window.0, &window.1).len(), 349);

    // Compaction doesn't disturb live snapshots either.
    let pre_compact = store.snapshot();
    store.compact();
    assert_eq!(
        store.query_window_on(&pre_compact, &window.0, &window.1).len(),
        349
    );
    assert_eq!(store.query_window(&window.0, &window.1).len(), 349);
}

/// Threaded stress: interleaved insert/delete/compact/query from
/// ×{1, 2, 5, 8} threads; afterwards every query face must equal a
/// freshly rebuilt `SfcIndex` on the live set.
#[test]
fn concurrent_mixed_workload_converges_to_index_parity() {
    for &threads in &[1usize, 2, 5, 8] {
        let d = 2usize;
        let level = 6u32;
        let store = SfcStore::new(
            d,
            level,
            CurveKind::Hilbert,
            vec![0.0, 0.0],
            &[100.0, 100.0],
            StoreConfig { shards: 4, buffer_rows: 64 },
        );
        // Pre-populate a victim set for the deleter.
        let seed_rows = Matrix::random(200, d, 11, 0.0, 100.0);
        let first = store.insert_batch(&seed_rows);
        let mut inserted: Vec<(u32, Vec<f32>)> = (0..200)
            .map(|i| (first + i as u32, seed_rows.row(i).to_vec()))
            .collect();
        let deleted: Vec<(u32, Vec<f32>)> = inserted.drain(0..100).collect();

        let writer_logs: Vec<Vec<(u32, Vec<f32>)>> = std::thread::scope(|scope| {
            let store = &store;
            // Writers: each inserts its own batches.
            let mut handles = Vec::new();
            for w in 0..threads {
                handles.push(scope.spawn(move || {
                    let mut rng = Rng::new(500 + w as u64);
                    let mut log = Vec::new();
                    for _ in 0..20 {
                        let n = 1 + rng.below(16) as usize;
                        let rows = Matrix::from_fn(n, d, |_, _| rng.f32() * 100.0);
                        let id0 = store.insert_batch(&rows);
                        for i in 0..n {
                            log.push((id0 + i as u32, rows.row(i).to_vec()));
                        }
                    }
                    log
                }));
            }
            // Deleter: removes the pre-populated victims.
            let victims = deleted.clone();
            let deleter = scope.spawn(move || {
                for (id, row) in &victims {
                    store.delete(*id, row);
                }
            });
            // Compactor: structural churn while everything else runs.
            let compactor = scope.spawn(move || {
                for i in 0..6 {
                    match i % 3 {
                        0 => store.flush(),
                        1 => store.compact(),
                        _ => store.rebalance(),
                    }
                }
            });
            // Readers: snapshot queries must stay internally sane.
            let reader = scope.spawn(move || {
                let mut rng = Rng::new(9999);
                for _ in 0..30 {
                    let lo: Vec<f32> = (0..d).map(|_| rng.f32() * 80.0).collect();
                    let hi: Vec<f32> = lo.iter().map(|&l| l + 15.0).collect();
                    let ids = store.query_window(&lo, &hi);
                    let mut dedup = ids.clone();
                    dedup.sort_unstable();
                    dedup.dedup();
                    assert_eq!(dedup.len(), ids.len(), "duplicate ids in a query result");
                }
            });
            let mut logs = Vec::new();
            for h in handles {
                logs.push(h.join().expect("writer panicked"));
            }
            deleter.join().expect("deleter panicked");
            compactor.join().expect("compactor panicked");
            reader.join().expect("reader panicked");
            logs
        });

        // Ground truth: survivors + everything the writers inserted.
        let mut alive: Alive = inserted.into_iter().collect();
        for log in writer_logs {
            for (id, row) in log {
                alive.insert(id, row);
            }
        }
        let mut rng = Rng::new(42);
        assert_parity(
            &store,
            &alive,
            d,
            level,
            CurveKind::Hilbert,
            &mut rng,
            &format!("threads={threads}"),
        );
    }
}

/// The store's query stats expose the serving shape: shards touched,
/// segments probed, and a NaN-free filter ratio on zero-candidate
/// queries.
#[test]
fn store_stats_report_sharding_and_guard_zero_candidates() {
    let points = sfc_mine::apps::simjoin::make_clustered(2000, 2, 30, 1.0, 13);
    let store = SfcStore::from_points(
        &points,
        7,
        CurveKind::Hilbert,
        StoreConfig { shards: 8, buffer_rows: 128 },
    );
    // A broad window crosses shards; stats say so.
    let (ids, stats) = store.query_window_stats(&[0.0, 0.0], &[100.0, 100.0], 0);
    assert!(!ids.is_empty());
    assert!(stats.shards_touched > 1, "broad window must cross shards");
    assert!(stats.segments_probed >= stats.shards_touched);
    assert!(stats.ranges >= 1);
    assert!(stats.filter_ratio() > 0.0);
    // A window far outside the data: no results, and the filter ratio
    // stays NaN-free (1.0 when the clamped window held no candidates,
    // 0.0 when edge-cell candidates were all filtered out).
    let (ids, stats) = store.query_window_stats(&[-500.0, -500.0], &[-400.0, -400.0], 0);
    assert!(ids.is_empty());
    assert_eq!(stats.results, 0);
    assert!(!stats.filter_ratio().is_nan());
    if stats.candidates == 0 {
        assert_eq!(stats.filter_ratio(), 1.0);
    } else {
        assert_eq!(stats.filter_ratio(), 0.0);
    }
    // The guard itself, directly: zero candidates ⇒ ratio 1.0.
    let zero = sfc_mine::index::QueryStats::default();
    assert_eq!(zero.filter_ratio(), 1.0);
    // Coarsening caps the global range count.
    let (exact, se) = store.query_window_stats(&[10.0, 10.0], &[60.0, 60.0], 0);
    let (coarse, sc) = store.query_window_stats(&[10.0, 10.0], &[60.0, 60.0], 3);
    assert!(sc.ranges <= 3);
    assert!(sc.candidates >= se.candidates);
    let mut a = exact;
    let mut b = coarse;
    a.sort_unstable();
    b.sort_unstable();
    assert_eq!(a, b, "coarsening must not change results");
}

/// Parallel maintenance through the query faces: after churn, fanned-out
/// `par_flush`/`par_compact`/`par_rebalance` leave every query face equal
/// to a fresh `SfcIndex` on the live set — same acceptance the serial
/// maintenance paths pass (byte-level serial parity lives in
/// `tests/sort.rs`).
#[test]
fn parallel_maintenance_keeps_query_parity() {
    let d = 2usize;
    let level = 6u32;
    let kind = CurveKind::Hilbert;
    let store = SfcStore::new(
        d,
        level,
        kind,
        vec![0.0, 0.0],
        &[100.0, 100.0],
        StoreConfig { shards: 4, buffer_rows: 32 },
    );
    let mut alive: Alive = Alive::new();
    let mut rng = Rng::new(77);
    let coord = Coordinator::new(3);
    for step in 0..6 {
        let n = 30 + rng.below(30) as usize;
        let rows = Matrix::from_fn(n, d, |_, _| rng.f32() * 100.0);
        let first = store.insert_batch(&rows);
        for i in 0..n {
            alive.insert(first + i as u32, rows.row(i).to_vec());
        }
        for _ in 0..rng.below(8) {
            if let Some((&id, row)) = alive.iter().next() {
                let row = row.clone();
                store.delete(id, &row);
                alive.remove(&id);
            }
        }
        match step % 3 {
            0 => store.par_flush(&coord),
            1 => store.par_compact(&coord),
            _ => store.par_rebalance(&coord),
        }
        assert_parity(&store, &alive, d, level, kind, &mut rng, &format!("par step={step}"));
    }
}

/// Batched snapshot queries through the coordinator agree with the
/// serial path at every thread count.
#[test]
fn batched_store_queries_scale_without_changing_results() {
    let points = sfc_mine::apps::simjoin::make_clustered(3000, 3, 40, 0.8, 17);
    let store = SfcStore::from_points(&points, 7, CurveKind::Hilbert, StoreConfig::default());
    let mut rng = Rng::new(23);
    let windows: Vec<(Vec<f32>, Vec<f32>)> = (0..60)
        .map(|_| {
            let p = rng.below_usize(points.rows);
            let lo: Vec<f32> = (0..3).map(|a| points.at(p, a) - 3.0).collect();
            let hi: Vec<f32> = (0..3).map(|a| points.at(p, a) + 3.0).collect();
            (lo, hi)
        })
        .collect();
    let snap = store.snapshot();
    let serial: Vec<Vec<u32>> = windows
        .iter()
        .map(|(lo, hi)| store.query_window_on(&snap, lo, hi))
        .collect();
    for threads in [1usize, 2, 4, 8] {
        let coord = Coordinator::new(threads);
        let par = coord.par_query_store(&store, &windows);
        assert_eq!(par, serial, "threads={threads}");
    }
}
