//! On-disk formats for the durable store: segment files and the manifest.
//!
//! Both formats are hand-rolled little-endian binary (the zero-dependency
//! constraint), versioned by magic + version word, and checksummed with
//! CRC-32 so corruption is *detected*, never silently served.
//!
//! ## Segment file (`seg-NNNNNNNNNN.sfc`)
//!
//! ```text
//! "SFCSEG1\0"  u32 version  u32 flags(bit0=sorted)  u32 dims  u64 rows
//! block(1, keys:   rows × u64)
//! block(2, ids:    rows × u32)
//! block(3, seqs:   rows × u64)
//! block(4, tombs:  ⌈rows/8⌉ bitset bytes)
//! block(5, points: rows × dims × f32)
//! block(6, footer: min/max key, fencepost key samples, bloom filter)
//! "SFCSEGE\0"
//! ```
//!
//! where `block(tag, payload)` is `u8 tag · u64 len · payload · u32
//! crc32(payload)`. The column blocks mirror [`Segment`]'s in-memory
//! layout, so encode/decode is a straight copy. The footer is redundant
//! validation metadata (and a future probe accelerator): decode
//! recomputes min/max, the every-16th-key fenceposts and the bloom
//! filter from the keys column and requires bitwise equality, on top of
//! verifying that the key column is actually sorted. A segment file
//! decodes to exactly the bytes that were encoded or fails with a clean
//! `InvalidData` error.
//!
//! ## Manifest (`MANIFEST-NNNNNNNNNN`)
//!
//! One self-contained generation of store metadata: curve/geometry
//! parameters (including raw quantizer origin/cell widths for bit-exact
//! re-keying), shard fenceposts, per-shard flushed-seq high-water marks
//! and run file lists, the live WAL name, and `next_seq`/`next_id`
//! counters. The trailing CRC covers the whole body; `CURRENT` names the
//! live manifest and is swapped atomically (temp file + rename), which
//! makes manifest publication the store's single commit point for
//! structural changes.

use crate::apps::Matrix;
use crate::curves::CurveKind;
use crate::index::quantize::Quantizer;
use std::io;

use super::segment::Segment;

pub(crate) const SEG_MAGIC: [u8; 8] = *b"SFCSEG1\0";
pub(crate) const SEG_END: [u8; 8] = *b"SFCSEGE\0";
pub(crate) const MAN_MAGIC: [u8; 8] = *b"SFCMAN1\0";
pub(crate) const FORMAT_VERSION: u32 = 1;

/// Key-sample stride for the footer fenceposts.
const FENCE_STRIDE: usize = 16;
/// Bloom filter: bits per key (rounded up to a power-of-two word count).
const BLOOM_BITS_PER_KEY: usize = 10;
const BLOOM_HASHES: u32 = 4;

const BLOCK_KEYS: u8 = 1;
const BLOCK_IDS: u8 = 2;
const BLOCK_SEQS: u8 = 3;
const BLOCK_TOMBS: u8 = 4;
const BLOCK_POINTS: u8 = 5;
const BLOCK_FOOTER: u8 = 6;

/// Clean decode failure (corruption, truncation, version skew).
pub(crate) fn bad(msg: impl Into<String>) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg.into())
}

pub(crate) fn to_usize(v: u64, what: &str) -> io::Result<usize> {
    usize::try_from(v).map_err(|_| bad(format!("{what} {v} overflows usize")))
}

pub(crate) fn to_u64(v: usize, what: &str) -> io::Result<u64> {
    u64::try_from(v).map_err(|_| bad(format!("{what} {v} overflows u64")))
}

// ---------------------------------------------------------------------------
// CRC-32 (IEEE, reflected) — table built at compile time.
// ---------------------------------------------------------------------------

const CRC_TABLE: [u32; 256] = {
    let mut table = [0u32; 256];
    let mut i = 0usize;
    while i < 256 {
        let mut c = i as u32;
        let mut j = 0;
        while j < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            j += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
};

/// CRC-32 (IEEE 802.3) of `bytes`.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

// ---------------------------------------------------------------------------
// Little-endian writer / bounds-checked reader.
// ---------------------------------------------------------------------------

pub(crate) fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn put_f32(out: &mut Vec<u8>, v: f32) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Bounds-checked little-endian cursor: every read is validated against
/// the remaining length and fails with a clean error on truncation, so
/// decoders never index out of bounds no matter how mangled the input.
pub(crate) struct Cur<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Cur<'a> {
    pub fn new(b: &'a [u8]) -> Self {
        Cur { b, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.b.len() - self.pos
    }

    pub fn take(&mut self, n: usize, what: &str) -> io::Result<&'a [u8]> {
        if self.remaining() < n {
            return Err(bad(format!(
                "truncated {what}: need {n} bytes, have {}",
                self.remaining()
            )));
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self, what: &str) -> io::Result<u8> {
        Ok(self.take(1, what)?[0])
    }

    pub fn u32(&mut self, what: &str) -> io::Result<u32> {
        let s = self.take(4, what)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    pub fn u64(&mut self, what: &str) -> io::Result<u64> {
        let s = self.take(8, what)?;
        Ok(u64::from_le_bytes([
            s[0], s[1], s[2], s[3], s[4], s[5], s[6], s[7],
        ]))
    }

    pub fn f32(&mut self, what: &str) -> io::Result<f32> {
        let s = self.take(4, what)?;
        Ok(f32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }
}

fn put_block(out: &mut Vec<u8>, tag: u8, payload: &[u8]) -> io::Result<()> {
    out.push(tag);
    put_u64(out, to_u64(payload.len(), "block length")?);
    out.extend_from_slice(payload);
    put_u32(out, crc32(payload));
    Ok(())
}

/// Read one `block(tag, …)`: checks the tag, that the declared length is
/// exactly `expect_len`, and the payload CRC.
fn take_block<'a>(cur: &mut Cur<'a>, tag: u8, expect_len: usize, what: &str) -> io::Result<&'a [u8]> {
    let got_tag = cur.u8(what)?;
    if got_tag != tag {
        return Err(bad(format!("{what}: block tag {got_tag}, expected {tag}")));
    }
    let len = to_usize(cur.u64(what)?, "block length")?;
    if len != expect_len {
        return Err(bad(format!(
            "{what}: block length {len}, expected {expect_len}"
        )));
    }
    let payload = cur.take(len, what)?;
    let crc = cur.u32(what)?;
    if crc != crc32(payload) {
        return Err(bad(format!("{what}: block checksum mismatch")));
    }
    Ok(payload)
}

// ---------------------------------------------------------------------------
// Footer metadata: min/max, fenceposts, bloom filter.
// ---------------------------------------------------------------------------

fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

fn bloom_words_for(rows: usize) -> usize {
    let bits = rows.saturating_mul(BLOOM_BITS_PER_KEY).max(64);
    let words = bits.div_ceil(64);
    words.next_power_of_two()
}

fn bloom_build(keys: &[u64]) -> Vec<u64> {
    let words = bloom_words_for(keys.len());
    let mask = (words as u64) * 64 - 1; // words is a power of two
    let mut bloom = vec![0u64; words];
    for &k in keys {
        let h1 = splitmix64(k);
        let h2 = splitmix64(h1) | 1;
        for i in 0..BLOOM_HASHES as u64 {
            let bit = h1.wrapping_add(i.wrapping_mul(h2)) & mask;
            bloom[(bit / 64) as usize] |= 1u64 << (bit % 64);
        }
    }
    bloom
}

fn fence_keys(keys: &[u64]) -> Vec<u64> {
    let mut fences: Vec<u64> = keys.iter().copied().step_by(FENCE_STRIDE).collect();
    if let Some(&last) = keys.last() {
        if fences.last() != Some(&last) {
            fences.push(last);
        }
    }
    fences
}

fn encode_footer(keys: &[u64]) -> io::Result<Vec<u8>> {
    let mut out = Vec::new();
    put_u64(&mut out, keys.first().copied().unwrap_or(0));
    put_u64(&mut out, keys.last().copied().unwrap_or(0));
    let fences = fence_keys(keys);
    put_u32(&mut out, u32::try_from(FENCE_STRIDE).expect("stride fits"));
    put_u32(
        &mut out,
        u32::try_from(fences.len()).map_err(|_| bad("too many fenceposts"))?,
    );
    for f in fences {
        put_u64(&mut out, f);
    }
    let bloom = bloom_build(keys);
    put_u32(&mut out, BLOOM_HASHES);
    put_u32(
        &mut out,
        u32::try_from(bloom.len()).map_err(|_| bad("bloom too large"))?,
    );
    for w in bloom {
        put_u64(&mut out, w);
    }
    Ok(out)
}

/// Validate a footer payload by recomputing every field from the decoded
/// key column and requiring bitwise equality.
fn check_footer(payload: &[u8], keys: &[u64]) -> io::Result<()> {
    let expected = encode_footer(keys)?;
    if payload != expected.as_slice() {
        return Err(bad("segment footer does not match key column"));
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Segment encode / decode.
// ---------------------------------------------------------------------------

/// Serialize a sorted segment. Only sorted runs are ever persisted (the
/// write buffer lives in the WAL), so unsorted input is a caller bug.
pub fn encode_segment(seg: &Segment, dims: usize) -> io::Result<Vec<u8>> {
    assert!(seg.sorted, "only sorted runs are persisted");
    assert_eq!(seg.points.cols, dims, "segment dims mismatch");
    let rows = seg.rows();
    let mut out = Vec::new();
    out.extend_from_slice(&SEG_MAGIC);
    put_u32(&mut out, FORMAT_VERSION);
    put_u32(&mut out, 1); // flags: sorted
    put_u32(&mut out, u32::try_from(dims).map_err(|_| bad("dims overflow"))?);
    put_u64(&mut out, to_u64(rows, "row count")?);

    let mut payload = Vec::with_capacity(rows * 8);
    for &k in &seg.keys {
        put_u64(&mut payload, k);
    }
    put_block(&mut out, BLOCK_KEYS, &payload)?;

    payload.clear();
    for &id in &seg.ids {
        put_u32(&mut payload, id);
    }
    put_block(&mut out, BLOCK_IDS, &payload)?;

    payload.clear();
    for &s in &seg.seqs {
        put_u64(&mut payload, s);
    }
    put_block(&mut out, BLOCK_SEQS, &payload)?;

    payload.clear();
    payload.resize(rows.div_ceil(8), 0u8);
    for (i, &t) in seg.tombs.iter().enumerate() {
        if t {
            payload[i / 8] |= 1u8 << (i % 8);
        }
    }
    put_block(&mut out, BLOCK_TOMBS, &payload)?;

    payload.clear();
    for &v in &seg.points.data {
        put_f32(&mut payload, v);
    }
    put_block(&mut out, BLOCK_POINTS, &payload)?;

    let footer = encode_footer(&seg.keys)?;
    put_block(&mut out, BLOCK_FOOTER, &footer)?;

    out.extend_from_slice(&SEG_END);
    Ok(out)
}

/// Decode and fully validate a segment file: magic/version/dims, every
/// block's length and CRC, key-column sortedness, and the footer's
/// min/max/fencepost/bloom redundancy. Never panics on corrupt input.
pub fn decode_segment(bytes: &[u8], dims: usize) -> io::Result<Segment> {
    let mut cur = Cur::new(bytes);
    if cur.take(8, "segment magic")? != SEG_MAGIC {
        return Err(bad("not a segment file (bad magic)"));
    }
    let version = cur.u32("segment version")?;
    if version != FORMAT_VERSION {
        return Err(bad(format!("unsupported segment version {version}")));
    }
    let flags = cur.u32("segment flags")?;
    if flags != 1 {
        return Err(bad(format!("unsupported segment flags {flags:#x}")));
    }
    let file_dims = to_usize(cur.u32("segment dims")?.into(), "dims")?;
    if file_dims != dims {
        return Err(bad(format!(
            "segment dims {file_dims}, store expects {dims}"
        )));
    }
    let rows = to_usize(cur.u64("segment rows")?, "row count")?;
    let col8 = rows
        .checked_mul(8)
        .ok_or_else(|| bad("row count overflows column size"))?;
    let col4 = rows * 4;
    let pts = rows
        .checked_mul(dims)
        .and_then(|c| c.checked_mul(4))
        .ok_or_else(|| bad("row count overflows points size"))?;

    let keys_raw = take_block(&mut cur, BLOCK_KEYS, col8, "keys block")?;
    let ids_raw = take_block(&mut cur, BLOCK_IDS, col4, "ids block")?;
    let seqs_raw = take_block(&mut cur, BLOCK_SEQS, col8, "seqs block")?;
    let tombs_raw = take_block(&mut cur, BLOCK_TOMBS, rows.div_ceil(8), "tombs block")?;
    let points_raw = take_block(&mut cur, BLOCK_POINTS, pts, "points block")?;

    let keys: Vec<u64> = keys_raw
        .chunks_exact(8)
        .map(|c| u64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]))
        .collect();
    let ids: Vec<u32> = ids_raw
        .chunks_exact(4)
        .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    let seqs: Vec<u64> = seqs_raw
        .chunks_exact(8)
        .map(|c| u64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]))
        .collect();
    let mut tombs = Vec::with_capacity(rows);
    for i in 0..rows {
        tombs.push(tombs_raw[i / 8] & (1u8 << (i % 8)) != 0);
    }
    // Trailing padding bits must be zero (canonical encoding).
    for i in rows..tombs_raw.len() * 8 {
        if tombs_raw[i / 8] & (1u8 << (i % 8)) != 0 {
            return Err(bad("tombstone bitset has nonzero padding"));
        }
    }
    let data: Vec<f32> = points_raw
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();

    if keys.windows(2).any(|w| w[0] > w[1]) {
        return Err(bad("segment key column is not sorted"));
    }
    let footer_raw = {
        // Footer length is data-dependent; read tag + declared length,
        // then verify by recomputation.
        let got_tag = cur.u8("footer block")?;
        if got_tag != BLOCK_FOOTER {
            return Err(bad(format!("footer block tag {got_tag}")));
        }
        let len = to_usize(cur.u64("footer length")?, "footer length")?;
        let payload = cur.take(len, "footer block")?;
        let crc = cur.u32("footer block")?;
        if crc != crc32(payload) {
            return Err(bad("footer checksum mismatch"));
        }
        payload
    };
    check_footer(footer_raw, &keys)?;

    if cur.take(8, "end magic")? != SEG_END {
        return Err(bad("segment end magic missing"));
    }
    if cur.remaining() != 0 {
        return Err(bad(format!(
            "{} trailing bytes after segment end",
            cur.remaining()
        )));
    }

    Ok(Segment {
        keys,
        ids,
        seqs,
        tombs,
        points: Matrix {
            rows,
            cols: dims,
            data,
        },
        sorted: true,
    })
}

// ---------------------------------------------------------------------------
// Manifest.
// ---------------------------------------------------------------------------

/// Per-shard durable metadata.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardManifest {
    /// Entries with `seq <= flushed_seq` are fully contained in the run
    /// files; WAL replay skips them.
    pub flushed_seq: u64,
    /// Run file names, oldest → newest.
    pub runs: Vec<String>,
}

/// One durable generation of store metadata — everything `open()` needs
/// to rebuild the exact pre-crash snapshot together with the run files
/// and the WAL tail.
#[derive(Debug, Clone, PartialEq)]
pub struct Manifest {
    pub gen: u64,
    pub kind: CurveKind,
    pub dims: usize,
    pub level: u32,
    pub side: u32,
    pub buffer_rows: usize,
    /// Raw quantizer parts ([`Quantizer::from_raw`]) for bit-exact keys.
    pub origin: Vec<f32>,
    pub cell: Vec<f32>,
    pub data_lo: Vec<f32>,
    pub data_hi: Vec<f32>,
    pub next_seq: u64,
    pub next_id: u32,
    /// Shard fenceposts (`shards + 1` entries).
    pub bounds: Vec<u64>,
    pub shards: Vec<ShardManifest>,
    /// Live WAL file name.
    pub wal: String,
}

impl Manifest {
    /// Rebuild the quantizer exactly as persisted.
    pub fn quantizer(&self) -> Quantizer {
        Quantizer::from_raw(self.origin.clone(), self.cell.clone(), self.side)
    }
}

fn put_name(out: &mut Vec<u8>, name: &str) -> io::Result<()> {
    let bytes = name.as_bytes();
    put_u32(
        out,
        u32::try_from(bytes.len()).map_err(|_| bad("file name too long"))?,
    );
    out.extend_from_slice(bytes);
    Ok(())
}

fn take_name(cur: &mut Cur<'_>, what: &str) -> io::Result<String> {
    let len = to_usize(cur.u32(what)?.into(), "name length")?;
    if len > 4096 {
        return Err(bad(format!("{what}: name length {len} implausible")));
    }
    let raw = cur.take(len, what)?;
    let name = std::str::from_utf8(raw)
        .map_err(|_| bad(format!("{what}: name is not utf-8")))?
        .to_string();
    if name.is_empty()
        || name.contains('/')
        || name.contains('\\')
        || name.contains('\0')
        || name == "."
        || name == ".."
    {
        return Err(bad(format!("{what}: illegal file name {name:?}")));
    }
    Ok(name)
}

/// Serialize a manifest (body + trailing CRC over everything after the
/// magic).
pub fn encode_manifest(m: &Manifest) -> io::Result<Vec<u8>> {
    let mut out = Vec::new();
    out.extend_from_slice(&MAN_MAGIC);
    put_u32(&mut out, FORMAT_VERSION);
    put_u64(&mut out, m.gen);
    put_name(&mut out, m.kind.name())?;
    put_u32(&mut out, u32::try_from(m.dims).map_err(|_| bad("dims overflow"))?);
    put_u32(&mut out, m.level);
    put_u32(&mut out, m.side);
    put_u64(&mut out, to_u64(m.buffer_rows, "buffer_rows")?);
    put_u64(&mut out, to_u64(m.shards.len(), "shard count")?);
    if m.origin.len() != m.dims
        || m.cell.len() != m.dims
        || m.data_lo.len() != m.dims
        || m.data_hi.len() != m.dims
    {
        return Err(bad("manifest axis vectors must have dims entries"));
    }
    for &v in m.origin.iter().chain(&m.cell).chain(&m.data_lo).chain(&m.data_hi) {
        put_f32(&mut out, v);
    }
    put_u64(&mut out, m.next_seq);
    put_u32(&mut out, m.next_id);
    if m.bounds.len() != m.shards.len() + 1 {
        return Err(bad("manifest bounds must have shards + 1 entries"));
    }
    for &b in &m.bounds {
        put_u64(&mut out, b);
    }
    for sh in &m.shards {
        put_u64(&mut out, sh.flushed_seq);
        put_u32(
            &mut out,
            u32::try_from(sh.runs.len()).map_err(|_| bad("too many runs"))?,
        );
        for name in &sh.runs {
            put_name(&mut out, name)?;
        }
    }
    put_name(&mut out, &m.wal)?;
    let crc = crc32(&out[8..]);
    put_u32(&mut out, crc);
    Ok(out)
}

/// Decode and validate a manifest: magic, version, trailing CRC, name
/// hygiene and structural lengths.
pub fn decode_manifest(bytes: &[u8]) -> io::Result<Manifest> {
    if bytes.len() < 12 || bytes[..8] != MAN_MAGIC {
        return Err(bad("not a manifest (bad magic)"));
    }
    let body = &bytes[8..bytes.len() - 4];
    let stored = {
        let t = &bytes[bytes.len() - 4..];
        u32::from_le_bytes([t[0], t[1], t[2], t[3]])
    };
    if crc32(body) != stored {
        return Err(bad("manifest checksum mismatch"));
    }
    let mut cur = Cur::new(body);
    let version = cur.u32("manifest version")?;
    if version != FORMAT_VERSION {
        return Err(bad(format!("unsupported manifest version {version}")));
    }
    let gen = cur.u64("manifest gen")?;
    let kind_name = take_name(&mut cur, "curve kind")?;
    let kind: CurveKind = kind_name
        .parse()
        .map_err(|_| bad(format!("unknown curve kind {kind_name:?}")))?;
    let dims = to_usize(cur.u32("dims")?.into(), "dims")?;
    if dims == 0 || dims > 64 {
        return Err(bad(format!("manifest dims {dims} out of range")));
    }
    let level = cur.u32("level")?;
    let side = cur.u32("side")?;
    if side == 0 {
        return Err(bad("manifest side must be positive"));
    }
    let buffer_rows = to_usize(cur.u64("buffer_rows")?, "buffer_rows")?;
    let shards = to_usize(cur.u64("shard count")?, "shard count")?;
    if shards == 0 || shards > 1 << 20 {
        return Err(bad(format!("manifest shard count {shards} out of range")));
    }
    let axis = |what: &str, cur: &mut Cur<'_>| -> io::Result<Vec<f32>> {
        let mut v = Vec::with_capacity(dims);
        for _ in 0..dims {
            v.push(cur.f32(what)?);
        }
        Ok(v)
    };
    let origin = axis("origin", &mut cur)?;
    let cell = axis("cell widths", &mut cur)?;
    let data_lo = axis("data_lo", &mut cur)?;
    let data_hi = axis("data_hi", &mut cur)?;
    let next_seq = cur.u64("next_seq")?;
    let next_id = cur.u32("next_id")?;
    let mut bounds = Vec::with_capacity(shards + 1);
    for _ in 0..=shards {
        bounds.push(cur.u64("bounds")?);
    }
    if bounds.windows(2).any(|w| w[0] > w[1]) {
        return Err(bad("manifest bounds are not sorted"));
    }
    let mut shard_manifests = Vec::with_capacity(shards);
    for _ in 0..shards {
        let flushed_seq = cur.u64("flushed_seq")?;
        let nruns = to_usize(cur.u32("run count")?.into(), "run count")?;
        if nruns > 1 << 20 {
            return Err(bad(format!("run count {nruns} implausible")));
        }
        let mut runs = Vec::with_capacity(nruns);
        for _ in 0..nruns {
            runs.push(take_name(&mut cur, "run file")?);
        }
        shard_manifests.push(ShardManifest { flushed_seq, runs });
    }
    let wal = take_name(&mut cur, "wal file")?;
    if cur.remaining() != 0 {
        return Err(bad(format!(
            "{} trailing bytes after manifest",
            cur.remaining()
        )));
    }
    Ok(Manifest {
        gen,
        kind,
        dims,
        level,
        side,
        buffer_rows,
        origin,
        cell,
        data_lo,
        data_hi,
        next_seq,
        next_id,
        bounds,
        shards: shard_manifests,
        wal,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::index::quantize::Quantizer;
    use crate::util::rng::Rng;

    fn sample_segment(rows: usize, dims: usize) -> Segment {
        let mapper = CurveKind::Hilbert.nd_mapper(dims, 5);
        let quant = Quantizer::from_bounds(vec![0.0; dims], &vec![32.0; dims], 32);
        let mut rng = Rng::new(7);
        let points = Matrix::from_fn(rows, dims, |_, _| rng.f32() * 32.0);
        let ids: Vec<u32> = (0..rows as u32).collect();
        let mut seg =
            Segment::from_rows(mapper.as_ref(), &quant, ids, points, false, 10).into_sorted();
        // Sprinkle tombstones so the bitset round-trips non-trivially.
        for i in (0..rows).step_by(5) {
            seg.tombs[i] = true;
        }
        seg
    }

    #[test]
    fn segment_roundtrip_bitwise() {
        for (rows, dims) in [(0usize, 2usize), (1, 2), (37, 2), (64, 3)] {
            let seg = sample_segment(rows, dims);
            let bytes = encode_segment(&seg, dims).unwrap();
            let back = decode_segment(&bytes, dims).unwrap();
            assert_eq!(back.keys, seg.keys);
            assert_eq!(back.ids, seg.ids);
            assert_eq!(back.seqs, seg.seqs);
            assert_eq!(back.tombs, seg.tombs);
            assert_eq!(back.points.data, seg.points.data);
            assert!(back.sorted);
        }
    }

    #[test]
    fn segment_decode_rejects_every_flip() {
        let seg = sample_segment(23, 2);
        let bytes = encode_segment(&seg, 2).unwrap();
        for off in 0..bytes.len() {
            let mut bad_bytes = bytes.clone();
            bad_bytes[off] ^= 0xFF;
            assert!(
                decode_segment(&bad_bytes, 2).is_err(),
                "flip at {off} went undetected"
            );
        }
        for cut in 0..bytes.len() {
            assert!(
                decode_segment(&bytes[..cut], 2).is_err(),
                "truncation to {cut} went undetected"
            );
        }
    }

    #[test]
    fn segment_dims_mismatch_rejected() {
        let seg = sample_segment(8, 2);
        let bytes = encode_segment(&seg, 2).unwrap();
        assert!(decode_segment(&bytes, 3).is_err());
    }

    #[test]
    fn manifest_roundtrip_and_rejects_flips() {
        let m = Manifest {
            gen: 42,
            kind: CurveKind::Peano,
            dims: 3,
            level: 4,
            side: 81,
            buffer_rows: 256,
            origin: vec![0.5, -1.0, 2.0],
            cell: vec![0.25, 0.25, 0.125],
            data_lo: vec![0.5, -1.0, 2.0],
            data_hi: vec![20.0, 19.0, 18.0],
            next_seq: 1001,
            next_id: 77,
            bounds: vec![0, 100, 200, 400, 1000],
            shards: vec![
                ShardManifest {
                    flushed_seq: 9,
                    runs: vec!["seg-0000000001.sfc".into(), "seg-0000000004.sfc".into()],
                },
                ShardManifest { flushed_seq: 0, runs: vec![] },
                ShardManifest {
                    flushed_seq: 1000,
                    runs: vec!["seg-0000000002.sfc".into()],
                },
                ShardManifest { flushed_seq: 3, runs: vec![] },
            ],
            wal: "wal-0000000042.log".into(),
        };
        let bytes = encode_manifest(&m).unwrap();
        assert_eq!(decode_manifest(&bytes).unwrap(), m);
        for off in 0..bytes.len() {
            let mut bad_bytes = bytes.clone();
            bad_bytes[off] ^= 0xFF;
            assert!(
                decode_manifest(&bad_bytes).is_err(),
                "manifest flip at {off} went undetected"
            );
        }
        for cut in 0..bytes.len() {
            assert!(decode_manifest(&bytes[..cut]).is_err());
        }
    }

    #[test]
    fn crc32_known_vector() {
        // The classic check value for CRC-32/IEEE.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
    }
}
