//! The range-routed query planner: decompose once, cut at shard
//! boundaries, route each piece to exactly the shard owning it.
//!
//! A window query against the sharded store is planned in three steps:
//! the float window quantizes through the shared
//! [`Quantizer`](crate::index::quantize::Quantizer), decomposes into
//! contiguous curve ranges (once, whatever the shard count), optionally
//! coarsens under the `max_ranges` cap, and the resulting range list is
//! split at the store's shard fenceposts
//! ([`split_ranges_at`](crate::curves::engine::split_ranges_at)) into
//! per-shard probe lists. Ranges and shard boundaries live on the same
//! curve-order axis, so the split is exact: every decomposed cell goes
//! to exactly one shard, and shards outside the window are never
//! touched.

use crate::curves::engine::{coarsen_ranges, split_ranges_at, CurveMapperNd};
use crate::index::quantize::Quantizer;
use std::ops::Range;

/// The probe list of one shard: which contiguous key ranges to
/// binary-search in that shard's segment stack.
#[derive(Clone, Debug)]
pub struct ShardProbe {
    /// Shard index (into the store's shard list).
    pub shard: usize,
    /// Sorted, disjoint key ranges, each fully inside the shard.
    pub ranges: Vec<Range<u64>>,
}

/// A planned window query: the global decomposition plus its routing.
#[derive(Clone, Debug, Default)]
pub struct QueryPlan {
    /// Global decomposition (after coarsening), in curve order.
    pub ranges: Vec<Range<u64>>,
    /// Per-shard probe lists, only for shards the window intersects.
    pub probes: Vec<ShardProbe>,
}

impl QueryPlan {
    /// Number of shards the plan touches.
    pub fn shards_touched(&self) -> usize {
        self.probes.len()
    }
}

/// Plan a window query: quantize + decompose the float window, coarsen
/// to `max_ranges` (0 = exact), split at the shard fenceposts `bounds`
/// (length `shards + 1`).
pub fn plan_window(
    mapper: &dyn CurveMapperNd,
    quant: &Quantizer,
    bounds: &[u64],
    lo: &[f32],
    hi: &[f32],
    max_ranges: usize,
) -> QueryPlan {
    let mut ranges = mapper.decompose_nd(&quant.window(lo, hi));
    coarsen_ranges(&mut ranges, max_ranges);
    plan_ranges(ranges, bounds)
}

/// Plan a **key-jump** probe: a sorted, deduplicated list of curve keys
/// (e.g. a neighbor stencil from
/// [`NeighborFinder`](crate::curves::neighbor::NeighborFinder)) is
/// merged into contiguous unit-cell runs and routed across the shard
/// fenceposts like any decomposed window. The jump path thereby reuses
/// the exact routing invariants of the window planner — every stencil
/// cell probes exactly one shard — without ever decomposing a window.
pub fn plan_keys(keys: &[u64], bounds: &[u64]) -> QueryPlan {
    debug_assert!(keys.windows(2).all(|w| w[0] < w[1]), "keys must be sorted and unique");
    let mut ranges: Vec<Range<u64>> = Vec::new();
    for &k in keys {
        match ranges.last_mut() {
            Some(r) if r.end == k => r.end = k + 1,
            _ => ranges.push(k..k + 1),
        }
    }
    plan_ranges(ranges, bounds)
}

/// Route an already-decomposed range list (sorted, disjoint) to shards.
pub fn plan_ranges(ranges: Vec<Range<u64>>, bounds: &[u64]) -> QueryPlan {
    let mut probes: Vec<ShardProbe> = Vec::new();
    for (shard, piece) in split_ranges_at(&ranges, bounds) {
        match probes.last_mut() {
            Some(p) if p.shard == shard => p.ranges.push(piece),
            _ => probes.push(ShardProbe { shard, ranges: vec![piece] }),
        }
    }
    QueryPlan { ranges, probes }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::curves::CurveKind;

    #[test]
    fn plan_covers_decomposition_exactly() {
        let mapper = CurveKind::Hilbert.nd_mapper(2, 6); // 64×64, span 4096
        let quant = Quantizer::from_bounds(vec![0.0, 0.0], &[64.0, 64.0], 64);
        let bounds = [0u64, 1024, 2048, 3072, 4096];
        let plan = plan_window(mapper.as_ref(), &quant, &bounds, &[10.0, 10.0], &[40.0, 40.0], 0);
        assert!(!plan.probes.is_empty());
        let global: u64 = plan.ranges.iter().map(|r| r.end - r.start).sum();
        let routed: u64 = plan
            .probes
            .iter()
            .flat_map(|p| p.ranges.iter())
            .map(|r| r.end - r.start)
            .sum();
        assert_eq!(global, routed, "every decomposed cell routes to one shard");
        for p in &plan.probes {
            for r in &p.ranges {
                assert!(bounds[p.shard] <= r.start && r.end <= bounds[p.shard + 1]);
            }
        }
        // Probes come out in shard order, one entry per touched shard.
        let shards: Vec<usize> = plan.probes.iter().map(|p| p.shard).collect();
        let mut dedup = shards.clone();
        dedup.dedup();
        assert_eq!(shards, dedup);
    }

    #[test]
    fn tiny_window_touches_one_shard() {
        let mapper = CurveKind::Hilbert.nd_mapper(2, 6);
        let quant = Quantizer::from_bounds(vec![0.0, 0.0], &[64.0, 64.0], 64);
        let bounds = [0u64, 2048, 4096];
        let plan =
            plan_window(mapper.as_ref(), &quant, &bounds, &[3.0, 3.0], &[3.5, 3.5], 0);
        assert_eq!(plan.shards_touched(), 1);
    }

    #[test]
    fn key_plan_merges_runs_and_routes_across_fenceposts() {
        let bounds = [0u64, 100, 200];
        let plan = plan_keys(&[3, 4, 5, 99, 100, 101, 150], &bounds);
        // Consecutive keys collapse into runs...
        assert_eq!(plan.ranges, vec![3..6, 99..102, 150..151]);
        // ...and the run straddling the fencepost splits at it.
        assert_eq!(plan.probes.len(), 2);
        assert_eq!(plan.probes[0].shard, 0);
        assert_eq!(plan.probes[0].ranges, vec![3..6, 99..100]);
        assert_eq!(plan.probes[1].shard, 1);
        assert_eq!(plan.probes[1].ranges, vec![100..102, 150..151]);
    }

    #[test]
    fn coarsening_caps_the_global_range_count() {
        let mapper = CurveKind::ZOrder.nd_mapper(2, 7);
        let quant = Quantizer::from_bounds(vec![0.0, 0.0], &[128.0, 128.0], 128);
        let bounds = [0u64, 16384];
        let exact =
            plan_window(mapper.as_ref(), &quant, &bounds, &[5.0, 60.0], &[70.0, 100.0], 0);
        let capped =
            plan_window(mapper.as_ref(), &quant, &bounds, &[5.0, 60.0], &[70.0, 100.0], 4);
        assert!(exact.ranges.len() > 4, "workload must actually fragment");
        assert!(capped.ranges.len() <= 4);
    }
}
