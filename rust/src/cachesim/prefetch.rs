//! Stream-prefetcher model: an N-stream, stride-detecting prefetcher in
//! front of an LRU cache.
//!
//! Modern cores hide sequential-miss latency with hardware stream
//! prefetchers; this model quantifies the interaction with traversal
//! order that the wallclock benches exhibit: the canonic order's long
//! unit-stride runs are prefetch-friendly (most of its misses become
//! *covered* misses), while a space-filling curve's short runs defeat
//! stride detection — even though the curve has far fewer raw misses.
//! Both effects are real; which dominates depends on how much of the miss
//! latency prefetch can actually hide (the `reports/prefetch_*.csv`
//! sweep).

use super::lru::LruCache;
use super::stats::CacheStats;
use super::trace::MemSink;

/// One tracked stream: last line, detected stride, confidence.
#[derive(Copy, Clone, Debug)]
struct Stream {
    last_line: u64,
    stride: i64,
    confidence: u8,
    lru_tick: u64,
}

/// Prefetch statistics.
#[derive(Copy, Clone, Debug, Default)]
pub struct PrefetchStats {
    /// Demand misses that a prefetch had already covered (latency hidden).
    pub covered_misses: u64,
    /// Demand misses with no covering prefetch (full latency).
    pub uncovered_misses: u64,
    /// Prefetches issued.
    pub issued: u64,
    /// Prefetched lines that were never demanded before eviction is not
    /// tracked per-line; `issued - covered_misses` bounds the waste.
    pub hits: u64,
}

/// An LRU cache fronted by an N-stream stride prefetcher.
///
/// On every demand access the prefetcher trains its streams; on a stride
/// match with confidence ≥ 2 it prefetches `depth` lines ahead into the
/// cache and marks them covered.
pub struct PrefetchingCache {
    cache: LruCache,
    streams: Vec<Stream>,
    covered: std::collections::HashSet<u64>,
    depth: u64,
    tick: u64,
    /// Statistics.
    pub stats: PrefetchStats,
}

impl PrefetchingCache {
    /// `capacity_lines`/`line_size` as in [`LruCache`]; `streams` tracked
    /// stride streams; `depth` lines prefetched ahead.
    pub fn new(capacity_lines: usize, line_size: u32, streams: usize, depth: u64) -> Self {
        PrefetchingCache {
            cache: LruCache::new(capacity_lines, line_size),
            streams: Vec::with_capacity(streams.max(1)),
            covered: std::collections::HashSet::new(),
            depth,
            tick: 0,
            stats: PrefetchStats::default(),
        }
    }

    /// Max streams tracked.
    fn max_streams(&self) -> usize {
        self.streams.capacity()
    }

    /// Demand-access one line.
    pub fn access_line(&mut self, line: u64) {
        self.tick += 1;
        let miss = self.cache.access_tag(line);
        if self.covered.remove(&line) {
            // The line was brought in (or at least requested) by a
            // prefetch: the demand access that would have stalled is
            // (mostly) hidden.
            self.stats.covered_misses += 1;
        } else if miss {
            self.stats.uncovered_misses += 1;
        } else {
            self.stats.hits += 1;
        }
        // Train streams: find one whose continuation matches.
        let mut trained = false;
        for s in self.streams.iter_mut() {
            let delta = line as i64 - s.last_line as i64;
            if delta == s.stride && delta != 0 {
                s.confidence = s.confidence.saturating_add(1);
                s.last_line = line;
                s.lru_tick = self.tick;
                trained = true;
                if s.confidence >= 2 {
                    // Issue prefetches ahead.
                    let (stride, last, conf) = (s.stride, s.last_line, s.confidence);
                    let _ = conf;
                    for k in 1..=self.depth {
                        let target = last as i64 + stride * k as i64;
                        if target >= 0 {
                            let t = target as u64;
                            // Prefetch fill: counts as cache insertion, not
                            // a demand access.
                            let was_miss = self.cache.access_tag(t);
                            // Do not let prefetch fills pollute demand stats.
                            self.cache.stats.accesses -= 1;
                            self.cache.stats.misses -= u64::from(was_miss);
                            if was_miss {
                                self.covered.insert(t);
                                self.stats.issued += 1;
                            }
                        }
                    }
                }
                break;
            }
            if delta != 0 && (delta.abs() as u64) <= 8 && s.confidence == 0 {
                // Retrain idle stream with the new stride.
                s.stride = delta;
                s.last_line = line;
                s.confidence = 1;
                s.lru_tick = self.tick;
                trained = true;
                break;
            }
        }
        if !trained {
            if self.streams.len() < self.max_streams() {
                self.streams.push(Stream {
                    last_line: line,
                    stride: 1,
                    confidence: 0,
                    lru_tick: self.tick,
                });
            } else if let Some(victim) = self
                .streams
                .iter_mut()
                .min_by_key(|s| (s.confidence, s.lru_tick))
            {
                *victim = Stream { last_line: line, stride: 1, confidence: 0, lru_tick: self.tick };
            }
        }
    }

    /// Demand-access statistics of the backing cache.
    pub fn cache_stats(&self) -> CacheStats {
        self.cache.stats
    }

    /// Effective cost under a latency model: hits cost 1, covered misses
    /// `covered_cost`, uncovered misses `miss_cost`.
    pub fn cost(&self, covered_cost: u64, miss_cost: u64) -> u64 {
        self.stats.hits
            + self.stats.covered_misses * covered_cost
            + self.stats.uncovered_misses * miss_cost
    }
}

impl MemSink for PrefetchingCache {
    #[inline]
    fn touch(&mut self, addr: u64, len: u32) {
        let shift = self.cache.line_size().trailing_zeros();
        let first = addr >> shift;
        let last = (addr + len.max(1) as u64 - 1) >> shift;
        for line in first..=last {
            self.access_line(line);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequential_stream_gets_covered() {
        let mut c = PrefetchingCache::new(64, 64, 4, 4);
        for line in 0..200u64 {
            c.access_line(line);
        }
        let s = c.stats;
        // After training, nearly all misses are covered by prefetch.
        assert!(
            s.covered_misses > 150,
            "covered {} uncovered {}",
            s.covered_misses,
            s.uncovered_misses
        );
        assert!(s.uncovered_misses < 20);
    }

    #[test]
    fn random_pattern_defeats_prefetcher() {
        let mut rng = crate::util::rng::Rng::new(9);
        let mut c = PrefetchingCache::new(64, 64, 4, 4);
        for _ in 0..500 {
            c.access_line(rng.below(100_000));
        }
        assert!(c.stats.covered_misses < c.stats.uncovered_misses / 5);
    }

    #[test]
    fn strided_stream_detected() {
        let mut c = PrefetchingCache::new(64, 64, 4, 4);
        for k in 0..100u64 {
            c.access_line(k * 3);
        }
        assert!(c.stats.covered_misses > 60);
    }

    #[test]
    fn canonic_more_prefetchable_than_hilbert_but_more_misses() {
        // The wallclock-vs-misses reconciliation, in one test: replay the
        // Fig-1 pair loop; canonic has MORE raw misses but a HIGHER
        // covered fraction.
        use crate::apps::pairloop::{trace_pairs, PairLoopConfig};
        use crate::curves::nonrecursive::HilbertIter;
        use crate::curves::CurveKind;
        let cfg = PairLoopConfig { n: 64, m: 64, object_bytes: 256 };
        let run = |order: &[(u32, u32)]| {
            let mut c = PrefetchingCache::new(
                (cfg.working_set() / 8 / 64) as usize,
                64,
                8,
                4,
            );
            trace_pairs(&cfg, order, &mut c);
            c
        };
        let canon = run(&CurveKind::Canonic.enumerate(64));
        let hilb = run(&HilbertIter::new(64).collect::<Vec<_>>());
        let raw = |c: &PrefetchingCache| c.stats.covered_misses + c.stats.uncovered_misses;
        assert!(raw(&canon) > raw(&hilb), "hilbert has fewer raw misses");
        let frac = |c: &PrefetchingCache| {
            c.stats.covered_misses as f64 / raw(c).max(1) as f64
        };
        assert!(
            frac(&canon) > frac(&hilb),
            "canonic is more prefetch-covered: {:.2} vs {:.2}",
            frac(&canon),
            frac(&hilb)
        );
    }

    #[test]
    fn prefetch_fills_do_not_pollute_demand_stats() {
        let mut c = PrefetchingCache::new(64, 64, 4, 8);
        for line in 0..50u64 {
            c.access_line(line);
        }
        let s = c.cache_stats();
        assert_eq!(s.accesses, 50, "only demand accesses counted");
    }

    #[test]
    fn cost_model_orders() {
        let mut seq = PrefetchingCache::new(32, 64, 4, 4);
        for line in 0..300u64 {
            seq.access_line(line);
        }
        let mut rnd = PrefetchingCache::new(32, 64, 4, 4);
        let mut rng = crate::util::rng::Rng::new(3);
        for _ in 0..300 {
            rnd.access_line(rng.below(1_000_000));
        }
        assert!(seq.cost(30, 200) < rnd.cost(30, 200));
    }
}
