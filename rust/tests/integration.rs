//! Cross-module integration tests (no PJRT required).

use sfc_mine::apps::cholesky::{cholesky_blocked, random_spd, residual, TrailingOrder};
use sfc_mine::apps::kmeans::{
    assign_naive, init_centroids, lloyd, make_blobs, Assigner, KMeans,
};
use sfc_mine::apps::matmul::{matmul_hilbert, matmul_transposed};
use sfc_mine::apps::pairloop::{fig1e_sweep, PairLoopConfig};
use sfc_mine::apps::simjoin::{join_bruteforce, join_fgf_hilbert, make_clustered, normalize};
use sfc_mine::apps::Matrix;
use sfc_mine::cachesim::{Hierarchy, HierarchyConfig, MemSink};
use sfc_mine::coordinator::{par_kmeans_step, Coordinator};
use sfc_mine::curves::fur::{general_hilbert_path, FurHilbert};
use sfc_mine::curves::nonrecursive::HilbertIter;
use sfc_mine::curves::CurveKind;

#[test]
fn fig1e_hilbert_wins_in_the_realistic_band() {
    // The paper's headline: at 5–20% cache, Hilbert beats nested loops by
    // a large factor.
    let n = 128u32;
    let cfg = PairLoopConfig { n, m: n, object_bytes: 256 };
    let orders = vec![
        (CurveKind::Canonic, CurveKind::Canonic.enumerate(n)),
        (CurveKind::Hilbert, HilbertIter::new(n).collect::<Vec<_>>()),
    ];
    let rows = fig1e_sweep(&cfg, &orders, &[0.05, 0.10, 0.20], 64);
    for r in &rows {
        let ratio = r.misses[0] as f64 / r.misses[1] as f64;
        assert!(
            ratio > 3.0,
            "at {:.0}% cache canonic/hilbert = {ratio:.1} (expected >3x)",
            r.cache_fraction * 100.0
        );
    }
}

#[test]
fn hierarchy_prefers_hilbert_matmul_trace() {
    // Replay the pair-loop trace of a blocked matmul through the full
    // L1/L2/TLB hierarchy: the Hilbert block order must cost less.
    let blocks = 32u32;
    let block_bytes = 4096u32; // one 32x32 f32 block
    let cost = |order: &[(u32, u32)]| {
        let mut h = Hierarchy::new(&HierarchyConfig::tiny());
        let cfg = PairLoopConfig { n: blocks, m: blocks, object_bytes: block_bytes };
        sfc_mine::apps::pairloop::trace_pairs(&cfg, order, &mut h);
        h.cost_cycles()
    };
    let canonic_cost = cost(&CurveKind::Canonic.enumerate(blocks));
    let hilbert_cost = cost(&HilbertIter::new(blocks).collect::<Vec<_>>());
    assert!(
        hilbert_cost < canonic_cost,
        "hierarchy cost: hilbert {hilbert_cost} vs canonic {canonic_cost}"
    );
}

#[test]
fn cholesky_reconstructs_via_hilbert_matmul() {
    // apps compose: factor with FGF-Hilbert traversal, reconstruct with
    // Hilbert matmul, compare against the original.
    let n = 48;
    let a = random_spd(n, 3);
    let mut l = a.clone();
    cholesky_blocked(&mut l, 16, TrailingOrder::Hilbert).unwrap();
    assert!(residual(&l, &a) < 1e-2);
    let lt = l.transposed();
    let rebuilt = matmul_hilbert(&l, &lt, 16);
    assert!(rebuilt.max_abs_diff(&a) < 1e-2);
}

#[test]
fn lloyd_full_run_all_assigners_same_fixed_point() {
    let (points, _) = make_blobs(400, 5, 4, 0.4, 17);
    let mut results = Vec::new();
    for assigner in [
        Assigner::Naive,
        Assigner::Blocked(64, 4),
        Assigner::Hilbert(64, 4),
    ] {
        let mut km = KMeans {
            points: points.clone(),
            centroids: init_centroids(&points, 5, 9),
        };
        let res = lloyd(&mut km, assigner, 40, 1e-10);
        assert!(res.converged, "{assigner:?} did not converge");
        results.push(res.assignment.labels);
    }
    assert_eq!(results[0], results[1]);
    assert_eq!(results[0], results[2]);
}

#[test]
fn coordinator_lloyd_matches_serial_lloyd() {
    let (points, _) = make_blobs(600, 8, 6, 0.5, 23);
    let centroids = init_centroids(&points, 8, 4);
    // Serial steps.
    let mut serial = KMeans { points: points.clone(), centroids: centroids.clone() };
    for _ in 0..5 {
        let a = assign_naive(&serial);
        serial.centroids = sfc_mine::apps::kmeans::update_centroids(&serial, &a);
    }
    // Coordinator steps.
    let coord = Coordinator::new(3);
    let mut par = KMeans { points, centroids };
    for _ in 0..5 {
        let (_, c) = par_kmeans_step(&coord, &par, 128, 8);
        par.centroids = c;
    }
    assert!(par.centroids.max_abs_diff(&serial.centroids) < 1e-3);
}

#[test]
fn simjoin_fgf_equals_bruteforce_many_workloads() {
    for seed in [1u64, 2, 3] {
        for eps in [0.6f32, 1.2] {
            let points = make_clustered(250, 3, 10, 0.7, seed);
            let (a, _) = join_bruteforce(&points, eps);
            let (b, _) = join_fgf_hilbert(&points, eps);
            assert_eq!(normalize(a), normalize(b), "seed={seed} eps={eps}");
        }
    }
}

#[test]
fn fur_trace_has_better_locality_than_roundup_filter() {
    // Iterating a skewed rectangle: FUR's traversal touches object rows
    // with fewer LRU misses than the round-up+filter traversal.
    let (n, m) = (48u32, 160u32);
    let cfg = PairLoopConfig { n, m, object_bytes: 256 };
    let np2 = n.max(m).next_power_of_two();
    let roundup: Vec<(u32, u32)> = HilbertIter::new(np2)
        .filter(|&(i, j)| i < n && j < m)
        .collect();
    let fur = FurHilbert::path(n, m);
    assert_eq!(roundup.len(), fur.len());
    let misses = |order: &[(u32, u32)]| {
        let mut cache = sfc_mine::cachesim::LruCache::with_bytes(cfg.working_set() / 8, 64);
        sfc_mine::apps::pairloop::trace_pairs(&cfg, order, &mut cache);
        cache.stats.misses
    };
    let m_fur = misses(&fur);
    let m_round = misses(&roundup);
    // FUR should be at least comparable (the filtered round-up keeps the
    // Hilbert shape but wastes generation; locality is similar) — assert
    // FUR within 1.5x and not pathological.
    assert!(
        (m_fur as f64) < (m_round as f64) * 1.5,
        "fur {m_fur} vs roundup {m_round}"
    );
}

#[test]
fn general_hilbert_feeds_matmul_blocks_completely() {
    // The block traversal used by matmul_hilbert covers every block pair
    // exactly once for awkward shapes.
    let (bi, bj) = (7u32, 13u32);
    let path = general_hilbert_path(bi, bj);
    assert_eq!(path.len(), (bi * bj) as usize);
    // And the resulting matmul is correct (cross-checked vs transposed).
    let b = Matrix::random(7 * 8, 13 * 8, 1, -1.0, 1.0);
    let c = Matrix::random(13 * 8, 7 * 8, 2, -1.0, 1.0);
    let x = matmul_hilbert(&b, &c, 8);
    let y = matmul_transposed(&b, &c);
    assert!(x.max_abs_diff(&y) < 1e-3);
}

#[test]
fn hierarchy_memsink_composes_with_pairloop() {
    let cfg = PairLoopConfig { n: 32, m: 32, object_bytes: 128 };
    let mut h = Hierarchy::new(&HierarchyConfig::tiny());
    let order: Vec<(u32, u32)> = HilbertIter::new(32).collect();
    sfc_mine::apps::pairloop::trace_pairs(&cfg, &order, &mut h);
    let stats = h.level_stats();
    assert!(stats[0].accesses > 0);
    assert!(stats[1].accesses <= stats[0].accesses);
    // TLB saw page-granular traffic.
    assert!(h.tlb_stats.accesses == stats[0].accesses);
}

#[test]
fn memsink_trait_object_safety() {
    // MemSink is usable as a trait object (apps take &mut dyn MemSink in
    // generic replay helpers).
    let mut cache = sfc_mine::cachesim::LruCache::new(4, 64);
    let sink: &mut dyn MemSink = &mut cache;
    sink.touch(0, 4);
    sink.touch_elem(1000, 3, 8);
    assert_eq!(cache.stats.accesses, 2);
}
