//! Sort/merge-engine bench (ISSUE 8): the comparison argsort vs the
//! stable LSD radix argsort vs the parallel sample-sort driver on curve
//! keys, the retired re-sort `Segment::merge` vs the streaming
//! loser-tree merge, and cold store build + post-churn maintenance
//! (serial vs parallel compact/rebalance) wall clock. Emits
//! `reports/bench_sort.json` so the sort keys/sec trajectory is
//! recorded.
//!
//! Every fast path first asserts **bit-for-bit** parity with its
//! reference on the same input — including tie order on duplicate-heavy
//! keys — before it is timed; the parallel maintenance paths must leave
//! the store byte-identical to the serial ones.
//!
//! Targets (acceptance): radix argsort ≥ 2× the comparison sort
//! single-threaded, sample-sort ≥ 4× at 8 threads, on ≥ 1M keys
//! (thresholds relaxed under `SFC_BENCH_FAST`, where the corpus shrinks
//! and CI runners have few cores).

use sfc_mine::apps::Matrix;
use sfc_mine::coordinator::Coordinator;
use sfc_mine::curves::engine::CurveMapperNd;
use sfc_mine::curves::ndim::HilbertNd;
use sfc_mine::curves::CurveKind;
use sfc_mine::index::quantize::Quantizer;
use sfc_mine::index::store::segment::Segment;
use sfc_mine::index::{SfcStore, Snapshot, StoreConfig};
use sfc_mine::util::bench::{fmt_dur, Bench, Measurement};
use sfc_mine::util::rng::Rng;
use sfc_mine::util::sort::{comparison_argsort, radix_argsort, sample_argsort};
use sfc_mine::util::table::Table;
use std::collections::HashMap;
use std::time::Instant;

fn write_json(bench: &Bench, path: &str) -> std::io::Result<()> {
    let mut s = String::from("[\n");
    for (idx, m) in bench.results().iter().enumerate() {
        if idx > 0 {
            s.push_str(",\n");
        }
        s.push_str(&format!(
            "  {{\"name\": \"{}\", \"median_ns\": {}, \"mad_ns\": {}, \"elements\": {}}}",
            m.name,
            m.median.as_nanos(),
            m.mad.as_nanos(),
            m.elements.unwrap_or(0)
        ));
    }
    s.push_str("\n]\n");
    if let Some(dir) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(path, s)
}

fn per_elem(m: &Measurement) -> f64 {
    m.median.as_nanos() as f64 / m.elements.unwrap_or(1) as f64
}

/// The retired `Segment::merge`: concatenate handles, globally sort,
/// resolve winners through a HashMap, emit with growing vectors — kept
/// here as the legacy baseline the streaming path is measured against.
fn merge_legacy(parts: &[&Segment], drop_tombs: bool, dims: usize) -> Segment {
    let total: usize = parts.iter().map(|s| s.rows()).sum();
    let mut handles: Vec<(u64, u64, u32, usize, usize)> = Vec::with_capacity(total);
    for (si, s) in parts.iter().enumerate() {
        for pos in 0..s.rows() {
            handles.push((s.keys[pos], s.seqs[pos], s.ids[pos], si, pos));
        }
    }
    handles.sort_unstable_by_key(|&(k, seq, id, _, _)| (k, seq, id));
    let mut winner = HashMap::<u32, usize>::with_capacity(total);
    for (idx, h) in handles.iter().enumerate() {
        match winner.entry(h.2) {
            std::collections::hash_map::Entry::Occupied(mut e) => {
                if h.1 > handles[*e.get()].1 {
                    e.insert(idx);
                }
            }
            std::collections::hash_map::Entry::Vacant(e) => {
                e.insert(idx);
            }
        }
    }
    let mut out = Segment {
        keys: Vec::new(),
        ids: Vec::new(),
        seqs: Vec::new(),
        tombs: Vec::new(),
        points: Matrix::zeros(0, dims),
        sorted: true,
    };
    for (idx, &(k, seq, id, si, pos)) in handles.iter().enumerate() {
        if winner[&id] != idx {
            continue;
        }
        let tomb = parts[si].tombs[pos];
        if tomb && drop_tombs {
            continue;
        }
        out.keys.push(k);
        out.seqs.push(seq);
        out.ids.push(id);
        out.tombs.push(tomb);
        out.points.data.extend_from_slice(parts[si].row(pos));
        out.points.rows += 1;
    }
    out
}

fn assert_seg_eq(a: &Segment, b: &Segment, ctx: &str) {
    assert_eq!(a.keys, b.keys, "{ctx}: keys");
    assert_eq!(a.ids, b.ids, "{ctx}: ids");
    assert_eq!(a.seqs, b.seqs, "{ctx}: seqs");
    assert_eq!(a.tombs, b.tombs, "{ctx}: tombs");
    assert_eq!(a.points.data, b.points.data, "{ctx}: row data");
}

fn assert_snap_eq(a: &Snapshot, b: &Snapshot, ctx: &str) {
    assert_eq!(a.bounds(), b.bounds(), "{ctx}: fenceposts");
    assert_eq!(a.entries(), b.entries(), "{ctx}: entries");
    let shards = a.bounds().len() - 1;
    for s in 0..shards {
        let (sa, sb) = (a.shard_segments(s), b.shard_segments(s));
        assert_eq!(sa.len(), sb.len(), "{ctx}: shard {s} segment count");
        for (x, y) in sa.iter().zip(sb) {
            assert_seg_eq(x, y, &format!("{ctx}: shard {s}"));
        }
    }
}

/// A post-churn store: bulk build, delete every third point, re-insert
/// a quarter of the rows under fresh ids — deterministic, so two calls
/// produce byte-identical stores to compare maintenance paths on.
fn churned_store(points: &Matrix, level: u32, cfg: StoreConfig) -> SfcStore {
    let store = SfcStore::from_points(points, level, CurveKind::Hilbert, cfg);
    for p in (0..points.rows).step_by(3) {
        store.delete(p as u32, points.row(p));
    }
    let quarter = points.rows / 4;
    let extra = Matrix {
        rows: quarter,
        cols: points.cols,
        data: points.data[..quarter * points.cols].to_vec(),
    };
    store.insert_batch(&extra);
    store
}

fn main() {
    let fast = std::env::var("SFC_BENCH_FAST").is_ok();
    let n: usize = if fast { 1 << 16 } else { 1 << 20 };
    let mut bench = Bench::new();
    let mut rng = Rng::new(2026);

    // --- argsort: comparison vs radix vs sample-sort -----------------------
    // Hilbert d=3 level-10 keys of random cube points — the key
    // distribution every index build and store flush actually sorts.
    let hil = HilbertNd::new(3, 10);
    let flat: Vec<u32> = (0..n * 3).map(|_| rng.below(1 << 10) as u32).collect();
    let mut keys: Vec<u64> = Vec::with_capacity(n);
    hil.order_batch_nd(&flat, &mut keys);

    // Parity before timing (acceptance): the radix and sample paths must
    // reproduce the comparison argsort bit-for-bit — on the bench corpus
    // and on a duplicate-heavy one where tie order is the whole story.
    let want = comparison_argsort(&keys);
    assert_eq!(radix_argsort(&keys), want, "radix != comparison on curve keys");
    let dups: Vec<u64> = (0..n).map(|_| rng.below(16)).collect();
    let want_dups = comparison_argsort(&dups);
    assert_eq!(radix_argsort(&dups), want_dups, "radix tie order diverged");
    for threads in [2usize, 4, 8] {
        let coord = Coordinator::new(threads);
        assert_eq!(
            sample_argsort(&keys, &coord),
            want,
            "sample-sort != comparison at {threads} threads"
        );
        assert_eq!(
            sample_argsort(&dups, &coord),
            want_dups,
            "sample-sort tie order diverged at {threads} threads"
        );
    }
    println!("sort parity: radix + sample-sort == comparison argsort (bit-for-bit, ties included)");

    let m_cmp = bench.throughput("argsort/comparison/1t", n as u64, || {
        comparison_argsort(&keys).len()
    });
    let m_radix =
        bench.throughput("argsort/radix/1t", n as u64, || radix_argsort(&keys).len());
    let mut tab = Table::new(vec!["path", "threads", "ns/key", "Mkeys/s", "vs comparison"]);
    let row = |tab: &mut Table, name: &str, threads: usize, m: &Measurement, base: &Measurement| {
        tab.row(vec![
            name.into(),
            threads.to_string(),
            format!("{:.2}", per_elem(m)),
            format!("{:.2}", 1e3 / per_elem(m)),
            format!("{:.2}x", per_elem(base) / per_elem(m)),
        ]);
    };
    row(&mut tab, "comparison", 1, &m_cmp, &m_cmp);
    row(&mut tab, "radix-lsd", 1, &m_radix, &m_cmp);
    let mut speedup8 = 0.0f64;
    for threads in [2usize, 4, 8] {
        let coord = Coordinator::new(threads);
        let m = bench.throughput(&format!("argsort/sample/{threads}t"), n as u64, || {
            sample_argsort(&keys, &coord).len()
        });
        row(&mut tab, "sample-sort", threads, &m, &m_cmp);
        if threads == 8 {
            speedup8 = per_elem(&m_cmp) / per_elem(&m);
        }
    }
    println!("\n== argsort on {n} Hilbert d3 keys ==");
    print!("{}", tab.render());
    let radix_speedup = per_elem(&m_cmp) / per_elem(&m_radix);
    let (radix_min, sample_min) = if fast { (1.2, 1.0) } else { (2.0, 4.0) };
    assert!(
        radix_speedup >= radix_min,
        "radix argsort must be ≥ {radix_min}x the comparison sort, got {radix_speedup:.2}x"
    );
    assert!(
        speedup8 >= sample_min,
        "sample-sort @8t must be ≥ {sample_min}x the comparison sort, got {speedup8:.2}x"
    );

    // --- Segment::merge: legacy re-sort vs streaming loser tree ------------
    let merge_rows: usize = if fast { 1 << 13 } else { 1 << 17 };
    let runs = 8usize;
    let per_run = merge_rows / runs;
    let mapper = CurveKind::Hilbert.nd_mapper(3, 8);
    let quant = Quantizer::from_bounds(vec![0.0; 3], &[256.0; 3], 1 << 8);
    let mut parts: Vec<Segment> = Vec::new();
    let mut seq = 1u64;
    let mut all_rows = Matrix::zeros(0, 3);
    for r in 0..runs {
        let tomb = r == runs - 1; // last run deletes earlier points
        let (ids, rows) = if tomb {
            let ids: Vec<u32> = (0..per_run as u32).map(|i| i * 3).collect();
            let mut rows = Matrix::zeros(0, 3);
            for &id in &ids {
                rows.data.extend_from_slice(all_rows.row(id as usize));
                rows.rows += 1;
            }
            (ids, rows)
        } else {
            let base = (r * per_run) as u32;
            let rows = Matrix::from_fn(per_run, 3, |_, _| rng.below(256) as f32);
            all_rows.data.extend_from_slice(&rows.data);
            all_rows.rows += rows.rows;
            ((base..base + per_run as u32).collect(), rows)
        };
        let mut s = Segment::from_rows(mapper.as_ref(), &quant, ids, rows, tomb, seq);
        seq += per_run as u64;
        if r % 2 == 0 {
            s = s.into_sorted(); // half sorted runs, half write-buffer minis
        }
        parts.push(s);
    }
    let refs: Vec<&Segment> = parts.iter().collect();
    for drop_tombs in [false, true] {
        assert_seg_eq(
            &Segment::merge(&refs, drop_tombs, 3),
            &merge_legacy(&refs, drop_tombs, 3),
            &format!("streaming merge (drop={drop_tombs})"),
        );
    }
    println!("\nmerge parity: streaming loser-tree merge == legacy re-sort merge (byte-identical)");
    let m_legacy = bench.throughput("merge/legacy_resort", merge_rows as u64, || {
        merge_legacy(&refs, true, 3).rows()
    });
    let m_stream = bench.throughput("merge/streaming", merge_rows as u64, || {
        Segment::merge(&refs, true, 3).rows()
    });
    println!(
        "== merge {merge_rows} rows x {runs} runs: legacy {:.1} Mrows/s vs streaming \
         {:.1} Mrows/s ({:.2}x) ==",
        1e3 / per_elem(&m_legacy),
        1e3 / per_elem(&m_stream),
        per_elem(&m_legacy) / per_elem(&m_stream)
    );

    // --- store: cold build + post-churn maintenance wall clock -------------
    let store_n: usize = if fast { 4_000 } else { 50_000 };
    let level = 8u32;
    let cfg = StoreConfig { shards: 8, buffer_rows: 256 };
    let points = Matrix::random(store_n, 3, 11, 0.0, 100.0);
    bench.throughput("store/cold_build", store_n as u64, || {
        SfcStore::from_points(&points, level, CurveKind::Hilbert, cfg).snapshot().entries()
    });

    let serial = churned_store(&points, level, cfg);
    let entries = serial.snapshot().entries();
    let t0 = Instant::now();
    serial.compact();
    let dt_serial = t0.elapsed();
    let t0 = Instant::now();
    serial.rebalance();
    let dt_serial_reb = t0.elapsed();
    println!(
        "\n== post-churn maintenance ({entries} entries, {} shards) ==",
        serial.shard_count()
    );
    println!(
        "  serial   compact {:>10}  rebalance {:>10}",
        fmt_dur(dt_serial),
        fmt_dur(dt_serial_reb)
    );
    for threads in [2usize, 8] {
        let coord = Coordinator::new(threads);
        let par = churned_store(&points, level, cfg);
        let t0 = Instant::now();
        par.par_compact(&coord);
        let dt = t0.elapsed();
        assert_snap_eq(&par.snapshot(), &serial_compacted(&points, level, cfg), "par_compact");
        let t0 = Instant::now();
        par.par_rebalance(&coord);
        let dt_reb = t0.elapsed();
        assert_snap_eq(&par.snapshot(), &serial.snapshot(), &format!("par_rebalance x{threads}"));
        println!(
            "  x{threads} par   compact {:>10}  rebalance {:>10}",
            fmt_dur(dt),
            fmt_dur(dt_reb)
        );
    }
    println!("maintenance parity: parallel compact/rebalance == serial (any thread count)");

    bench.write_csv("reports/bench_sort.csv").unwrap();
    write_json(&bench, "reports/bench_sort.json").unwrap();
    println!("\nreports: reports/bench_sort.{{csv,json}}");
}

/// The serially-compacted (not yet rebalanced) reference snapshot,
/// rebuilt fresh so each parallel run compares against the same state.
fn serial_compacted(
    points: &Matrix,
    level: u32,
    cfg: StoreConfig,
) -> std::sync::Arc<Snapshot> {
    let store = churned_store(points, level, cfg);
    store.compact();
    store.snapshot()
}
