//! sfc-mine CLI: the Layer-3 launcher.
//!
//! ```text
//! sfc-mine info                         # platform + artifact status
//! sfc-mine fig1  [--n 256]              # regenerate Figure 1(e)
//! sfc-mine curves [--n 64]              # 2-D locality comparison table
//! sfc-mine curves --dims 3 [--level 3]  # d-dim locality comparison table
//! sfc-mine matmul [--n 512 --tile 32 --curve hilbert]  # §7 matmul variants
//! sfc-mine linalg [--app matmul --n 512 --tile 32 --curve hilbert
//!                  --threads 0 --simulate-cache]  # curve-tiled linalg suite
//! sfc-mine kmeans [--n 40960 --shard hilbert]  # parallel k-means loop
//! sfc-mine simjoin [--n 20000 --eps 1 --index-dims 3]  # §7 join variants
//! sfc-mine query [--mode point|window|knn --curve hilbert --dims 2
//!                 --level 8 --max-ranges 0]   # SfcIndex query subsystem
//! sfc-mine store [--n 20000 --dims 3 --shards 8 --ops 20000
//!                 --threads 0 --dir path --sync always|N|never]
//!                                # sharded mutable store: mixed workload;
//!                                # --dir persists it (and reopens+verifies
//!                                # an existing store after a crash)
//! sfc-mine serve [--n 100000 --qps 20000 --seconds 5 --producers 4
//!                 --replicas 3 --maintenance-threads 2
//!                 --scenario uniform|trajectory]
//!                                # serving pipeline under sustained churn:
//!                                # backpressured async ingest + replicated
//!                                # query tier, p50/p99/p999 under load
//! ```
//!
//! All curve dispatch goes through the engine ([`CurveKind::mapper`] /
//! [`CurveKind::rect_mapper`] / [`CurveKind::nd_mapper`]); `--curve`
//! accepts any `canonic|zorder|gray|hilbert|peano`, and `--dims d`
//! switches the locality table to the true d-dimensional curves. The
//! similarity join indexes the full dimensionality (capped via
//! `--index-dims`), drives its default path through the window→range
//! decomposition (`join_sfc`) and reports the legacy baselines next to
//! it; `kmeans --shard hilbert` pre-sorts points along their d-dim
//! Hilbert rank so worker shards are spatially compact. The `query`
//! command builds an order-sorted `SfcIndex` and reports
//! ranges-per-query, selectivity and the exact-filter ratio against a
//! full-scan baseline, per curve. The `store` command drives the
//! sharded, mutable `SfcStore` through a bulk ingest plus a mixed
//! insert/delete/query phase, asserts recall 1.0 against a freshly
//! rebuilt `SfcIndex` on the live set, and reports snapshot-query
//! thread scaling. The `serve` command runs the full serving pipeline
//! — async backpressured ingestion, background maintenance workers and
//! the replicated query router — under a sustained mixed workload at a
//! target QPS, reports p50/p99/p999 query latency under churn vs
//! quiescence, then drains and asserts bit-for-bit parity against a
//! fresh `SfcIndex` (`--scenario trajectory` ingests (x, y, t) points
//! and expires a sliding time window via range deletes through the
//! pipeline).

use sfc_mine::apps::kmeans::{hilbert_point_order, init_centroids, make_blobs, permute_rows, KMeans};
use sfc_mine::apps::matmul::{flops, matmul_curve, matmul_tiled, matmul_transposed};
use sfc_mine::apps::pairloop::{fig1e_sweep, PairLoopConfig};
use sfc_mine::apps::simjoin::{
    join_fgf_hilbert_dims, join_grid_nested_dims, join_grid_projected, join_sfc_decompose_dims,
    join_sfc_dims, join_store_decompose_dims, join_store_dims, make_clustered, DEFAULT_INDEX_DIMS,
};
use sfc_mine::apps::Matrix;
use sfc_mine::coordinator::{par_kmeans_step, Coordinator};
use sfc_mine::curves::engine::{collect_nd, CurveMapperNd};
use sfc_mine::curves::{metrics, CurveKind};
use sfc_mine::index::SfcIndex;
use sfc_mine::runtime::{artifact, Engine};
use sfc_mine::util::cli::Args;
use sfc_mine::util::latency::{fmt_ns, LatencyHistogram};
use sfc_mine::util::rng::Rng;
use sfc_mine::util::table::Table;
use std::time::Instant;

fn main() {
    let args = Args::from_env();
    match args.subcommand() {
        Some("info") => info(),
        Some("fig1") => fig1(&args),
        Some("curves") => curves(&args),
        Some("matmul") => matmul_cmd(&args),
        Some("linalg") => linalg_cmd(&args),
        Some("kmeans") => kmeans_cmd(&args),
        Some("simjoin") => simjoin_cmd(&args),
        Some("query") => query_cmd(&args),
        Some("store") => store_cmd(&args),
        Some("serve") => serve_cmd(&args),
        other => {
            if let Some(cmd) = other {
                eprintln!("unknown command '{cmd}'\n");
            }
            eprintln!(
                "usage: sfc-mine <info|fig1|curves|matmul|linalg|kmeans|simjoin|query|store|serve> \
                 [--key value]…\n\
                 see README.md for options"
            );
            std::process::exit(2);
        }
    }
}

fn info() {
    println!(
        "sfc-mine {} — space-filling curves for high-performance data mining",
        env!("CARGO_PKG_VERSION")
    );
    println!(
        "cores: {}",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    );
    match Engine::cpu() {
        Ok(engine) => println!("pjrt:  {}", engine.platform()),
        Err(e) => println!("pjrt:  unavailable ({e})"),
    }
    let dir = artifact::default_dir();
    match sfc_mine::runtime::Manifest::load(&dir) {
        Ok(m) => println!("artifacts at {}: {:?}", dir.display(), m.names()),
        Err(_) => println!("artifacts at {}: none (run `make artifacts`)", dir.display()),
    }
}

fn fig1(args: &Args) {
    let n: u32 = args.get("n", 256);
    let n = n.next_power_of_two();
    let obj: u32 = args.get("object-bytes", 256);
    let cfg = PairLoopConfig { n, m: n, object_bytes: obj };
    let orders = vec![
        (CurveKind::Canonic, CurveKind::Canonic.enumerate(n)),
        (CurveKind::ZOrder, CurveKind::ZOrder.enumerate(n)),
        (CurveKind::Hilbert, CurveKind::Hilbert.enumerate(n)),
    ];
    let fractions = [0.05, 0.10, 0.15, 0.20, 0.30, 0.50];
    let rows = fig1e_sweep(&cfg, &orders, &fractions, 64);
    let mut t = Table::new(vec!["cache %", "canonic", "zorder", "hilbert", "canonic/hilbert"]);
    for r in &rows {
        t.row(vec![
            format!("{:.0}%", r.cache_fraction * 100.0),
            r.misses[0].to_string(),
            r.misses[1].to_string(),
            r.misses[2].to_string(),
            format!("{:.1}x", r.misses[0] as f64 / r.misses[2] as f64),
        ]);
    }
    println!("Fig 1(e): LRU misses, {n}x{n} pair loop, {obj}-byte objects");
    print!("{}", t.render());
}

fn curves(args: &Args) {
    let dims: usize = args.get("dims", 2);
    if dims > 2 {
        return curves_nd(args, dims);
    }
    let n: u32 = args.get("n", 64);
    let w: usize = args.get("window", 64);
    let mut t = Table::new(vec!["curve", "avg step", "max step", "locality score"]);
    for kind in CurveKind::ALL {
        let path = kind.enumerate(n);
        let s = metrics::step_stats(&path);
        t.row(vec![
            kind.name().to_string(),
            format!("{:.3}", s.avg),
            s.max.to_string(),
            format!("{:.2}", metrics::locality_score(&path, w)),
        ]);
    }
    println!("curve locality on {n}x{n} (window {w}):");
    print!("{}", t.render());
}

/// d-dimensional locality table: true d-dim curves over their natural
/// hypercubes (side `2^level`; Peano `3^level`). The level is clamped
/// *per curve* — before any mapper is constructed, since the
/// constructors assert their domain fits `u64` — so every row stays
/// inside the table's cell budget (`2^22` cells for the 2-adic curves,
/// `3^12` for Peano).
fn curves_nd(args: &Args, dims: usize) {
    if dims > 13 {
        eprintln!("--dims {dims} unsupported (3..=13; the d-dim Peano caps at 13 dimensions)");
        std::process::exit(2);
    }
    let level: u32 = args.get("level", 3);
    let w: usize = args.get("window", 64);
    let mut t =
        Table::new(vec!["curve", "side", "cells", "avg step", "max step", "locality score"]);
    for kind in CurveKind::ALL {
        let max_lvl = match kind {
            CurveKind::Peano => (12 / dims as u32).max(1),
            _ => (22 / dims as u32).max(1),
        };
        let lvl = level.clamp(1, max_lvl);
        let mapper = kind.nd_mapper(dims, lvl);
        let side = match mapper.domain_nd() {
            sfc_mine::curves::engine::DomainNd::HyperRect { shape } => shape[0],
            _ => 0,
        };
        let path = collect_nd(mapper.as_ref());
        let s = metrics::step_stats_nd(&path, dims);
        t.row(vec![
            kind.name().to_string(),
            side.to_string(),
            (path.len() / dims).to_string(),
            format!("{:.3}", s.avg),
            s.max.to_string(),
            format!("{:.2}", metrics::locality_score_nd(&path, dims, w)),
        ]);
    }
    println!("curve locality in {dims}-d at level {level} (window {w}):");
    print!("{}", t.render());
}

fn matmul_cmd(args: &Args) {
    let n: usize = args.get("n", 512);
    let tile: usize = args.get("tile", 32);
    let curve: CurveKind = match args.get_str("curve", "hilbert").parse() {
        Ok(k) => k,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };
    let b = Matrix::random(n, n, 1, -1.0, 1.0);
    let c = Matrix::random(n, n, 2, -1.0, 1.0);
    let mut t = Table::new(vec!["variant", "ms", "GFLOP/s"]);
    for (name, f) in [
        (
            "transposed",
            Box::new(|| matmul_transposed(&b, &c)) as Box<dyn Fn() -> Matrix>,
        ),
        ("tiled", Box::new(|| matmul_tiled(&b, &c, tile))),
        (curve.name(), Box::new(|| matmul_curve(&b, &c, tile, curve))),
    ] {
        let t0 = Instant::now();
        std::hint::black_box(f());
        let dt = t0.elapsed();
        t.row(vec![
            name.to_string(),
            format!("{:.1}", dt.as_secs_f64() * 1e3),
            format!("{:.2}", flops(n, n, n) as f64 / dt.as_secs_f64() / 1e9),
        ]);
    }
    println!("matmul n={n} tile={tile} curve={}:", curve.name());
    print!("{}", t.render());
}

/// The `linalg` subcommand: the cache-oblivious linear-algebra suite on
/// curve-tiled storage — wallclock table for the baselines vs the
/// sequential and parallel curve-tiled kernels (results asserted equal),
/// plus, with `--simulate-cache`, the deterministic L1/L2 miss-rate
/// report (canonic vs tiled vs curve-tiled, per-matrix attribution).
fn linalg_cmd(args: &Args) {
    use sfc_mine::apps::{cholesky, floyd, matmul as mm};
    use sfc_mine::linalg::{simulate, LinalgApp, SimVariant, TiledMatrix};

    let app: LinalgApp = match args.get_str("app", "matmul").parse() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };
    let n: usize = args.get("n", 512);
    let tile: usize = args.get("tile", 32);
    let threads: usize = args.get("threads", 0);
    let curve: CurveKind = match args.get_str("curve", "hilbert").parse() {
        Ok(k) => k,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };
    let coord = Coordinator::new(threads);
    println!(
        "linalg app={} n={n} tile={tile} curve={} threads={}",
        app.name(),
        curve.name(),
        coord.threads()
    );

    let mut t = Table::new(vec!["variant", "ms", "GFLOP/s"]);
    let gflops = |dt: std::time::Duration| {
        format!("{:.2}", app.flops(n) as f64 / dt.as_secs_f64() / 1e9)
    };
    match app {
        LinalgApp::Matmul => {
            let b = Matrix::random(n, n, 1, -1.0, 1.0);
            let c = Matrix::random(n, n, 2, -1.0, 1.0);
            let t0 = Instant::now();
            std::hint::black_box(matmul_tiled(&b, &c, tile));
            let tiled_dt = t0.elapsed();
            let bt = TiledMatrix::from_matrix(&b, tile, curve);
            let ct = TiledMatrix::from_matrix(&c, tile, curve);
            let t0 = Instant::now();
            let seq = mm::matmul_tiles(&bt, &ct);
            let seq_dt = t0.elapsed();
            let t0 = Instant::now();
            let par = mm::par_matmul_tiles(&coord, &bt, &ct);
            let par_dt = t0.elapsed();
            assert_eq!(seq.data, par.data, "parallel must equal sequential bitwise");
            t.row(vec!["tiled (row-major)".into(), fmt_ms(tiled_dt), gflops(tiled_dt)]);
            t.row(vec!["curve-tiled seq".into(), fmt_ms(seq_dt), gflops(seq_dt)]);
            t.row(vec![
                format!("curve-tiled par x{}", coord.threads()),
                fmt_ms(par_dt),
                gflops(par_dt),
            ]);
        }
        LinalgApp::Cholesky => {
            let a = cholesky::random_spd(n, 7);
            let mut base = a.clone();
            let t0 = Instant::now();
            cholesky::cholesky_blocked(&mut base, tile, cholesky::TrailingOrder::Canonic)
                .expect("SPD input");
            let blocked_dt = t0.elapsed();
            let mut seq = TiledMatrix::from_matrix(&a, tile, curve);
            let t0 = Instant::now();
            cholesky::cholesky_tiles(&mut seq).expect("SPD input");
            let seq_dt = t0.elapsed();
            let mut par = TiledMatrix::from_matrix(&a, tile, curve);
            let t0 = Instant::now();
            cholesky::par_cholesky_tiles(&coord, &mut par).expect("SPD input");
            let par_dt = t0.elapsed();
            assert_eq!(seq.data, par.data, "parallel must equal sequential bitwise");
            let l = seq.to_matrix();
            let d = l.max_abs_diff(&base);
            assert!(d < 1e-2 * n as f32, "tiles vs blocked diverged: {d}");
            t.row(vec!["blocked (row-major)".into(), fmt_ms(blocked_dt), gflops(blocked_dt)]);
            t.row(vec!["curve-tiled seq".into(), fmt_ms(seq_dt), gflops(seq_dt)]);
            t.row(vec![
                format!("curve-tiled par x{}", coord.threads()),
                fmt_ms(par_dt),
                gflops(par_dt),
            ]);
        }
        LinalgApp::Floyd => {
            let g = floyd::random_graph(n, 0.3, 11);
            let mut canonic = g.clone();
            let t0 = Instant::now();
            floyd::floyd_canonic(&mut canonic);
            let canonic_dt = t0.elapsed();
            let mut seq = TiledMatrix::from_matrix(&g, tile, curve);
            let t0 = Instant::now();
            floyd::floyd_tiles(&mut seq);
            let seq_dt = t0.elapsed();
            let mut par = TiledMatrix::from_matrix(&g, tile, curve);
            let t0 = Instant::now();
            floyd::par_floyd_tiles(&coord, &mut par);
            let par_dt = t0.elapsed();
            assert_eq!(seq.data, par.data, "parallel must equal sequential bitwise");
            assert_eq!(seq.to_matrix().data, canonic.data, "tiles must equal canonic exactly");
            t.row(vec!["canonic".into(), fmt_ms(canonic_dt), gflops(canonic_dt)]);
            t.row(vec!["curve-tiled seq".into(), fmt_ms(seq_dt), gflops(seq_dt)]);
            t.row(vec![
                format!("curve-tiled par x{}", coord.threads()),
                fmt_ms(par_dt),
                gflops(par_dt),
            ]);
        }
    }
    print!("{}", t.render());

    if args.flag("simulate-cache") {
        let sim_n: usize = args.get("sim-n", n);
        println!(
            "\nsimulated misses (L1 32K/8w + L2 256K/8w, 64B lines) at n={sim_n} tile={tile}:"
        );
        let mut st = Table::new(vec![
            "variant",
            "L1 misses",
            "L2 misses",
            "L1+L2",
            "L1/kflop",
            "L2/kflop",
            "hottest region (L2 misses)",
        ]);
        let mut reports = Vec::new();
        for variant in SimVariant::ALL {
            let r = simulate(app, variant, sim_n, tile, curve);
            let hot = r
                .regions
                .iter()
                .max_by_key(|(_, s)| s.level_misses.get(1).copied().unwrap_or(0))
                .map(|(l, s)| format!("{l} ({})", s.level_misses.get(1).copied().unwrap_or(0)))
                .unwrap_or_else(|| "-".into());
            st.row(vec![
                match r.curve {
                    Some(c) => format!("{} [{c}]", r.variant),
                    None => r.variant.to_string(),
                },
                r.levels[0].misses.to_string(),
                r.levels[1].misses.to_string(),
                r.l12_misses().to_string(),
                format!("{:.3}", r.misses_per_kflop(0)),
                format!("{:.3}", r.misses_per_kflop(1)),
                hot,
            ]);
            reports.push(r);
        }
        print!("{}", st.render());
        let (canonic, curve_tiled) = (&reports[0], &reports[2]);
        let ratio = canonic.l12_misses() as f64 / curve_tiled.l12_misses().max(1) as f64;
        if ratio >= 1.0 {
            println!("curve-tiled takes {ratio:.1}x fewer L1+L2 misses than canonic");
        } else {
            // Floyd's per-pivot sweep is bandwidth-bound: the layout is
            // miss-neutral there (the win is the parallel wavefront).
            println!(
                "curve-tiled ≈ canonic on L1+L2 misses ({:.2}x) — bandwidth-bound sweep",
                1.0 / ratio
            );
        }
    }
}

/// Milliseconds with one decimal, for the timing tables.
fn fmt_ms(dt: std::time::Duration) -> String {
    format!("{:.1}", dt.as_secs_f64() * 1e3)
}

fn kmeans_cmd(args: &Args) {
    let n: usize = args.get("n", 40_960);
    let k: usize = args.get("k", 64);
    let d: usize = args.get("d", 16);
    let iters: usize = args.get("iters", 10);
    let threads: usize = args.get("threads", 0);
    let shard = args.get_str("shard", "hilbert");
    let (points, _) = make_blobs(n, k, d, 0.6, 42);
    let points = match shard.as_str() {
        // Pre-sort along the d-dim Hilbert rank: the coordinator's
        // contiguous point shards become spatially compact blobs.
        "hilbert" => permute_rows(&points, &hilbert_point_order(&points)),
        "input" => points,
        other => {
            eprintln!("unknown shard order '{other}' (hilbert|input)");
            std::process::exit(2);
        }
    };
    let centroids = init_centroids(&points, k, 7);
    let mut km = KMeans { points, centroids };
    let coord = Coordinator::new(threads);
    println!(
        "k-means n={n} k={k} d={d}, {} workers (Hilbert-blocked assignment, {shard} shards)",
        coord.threads()
    );
    for it in 0..iters {
        let t0 = Instant::now();
        let (assign, new_centroids) = par_kmeans_step(&coord, &km, 256, 16);
        km.centroids = new_centroids;
        println!(
            "iter {it:>3}: inertia {:>14.1}  ({:.1} ms)",
            assign.inertia(),
            t0.elapsed().as_secs_f64() * 1e3
        );
    }
}

fn simjoin_cmd(args: &Args) {
    let n: usize = args.get("n", 20_000);
    let eps: f32 = args.get("eps", 1.0);
    let d: usize = args.get("d", 8);
    let index_dims: usize = args.get("index-dims", d.clamp(1, DEFAULT_INDEX_DIMS));
    let index_dims = index_dims.clamp(1, d);
    let points = make_clustered(n, d, 40, 0.8, 7);

    // Baseline: the legacy 2-D projection index (cells over dims 0–1).
    let t0 = Instant::now();
    let (pairs_2d, s2) = join_grid_projected(&points, eps);
    let proj_dt = t0.elapsed();

    // Full-dimensional grid index, canonic cell-pair order.
    let t0 = Instant::now();
    let (pairs_grid, sg) = join_grid_nested_dims(&points, eps, index_dims);
    let grid_dt = t0.elapsed();

    // Full-dimensional grid index, FGF-Hilbert jump-over order.
    let t0 = Instant::now();
    let (pairs_fgf, sf) = join_fgf_hilbert_dims(&points, eps, index_dims);
    let fgf_dt = t0.elapsed();

    // The default path: stencil key jumps over the sorted Hilbert key
    // column (the constant-time neighbor operator driving the join).
    let t0 = Instant::now();
    let (pairs_sfc, ss) = join_sfc_dims(&points, eps, index_dims);
    let sfc_dt = t0.elapsed();

    // The retired per-cell window-decomposition loop, kept as the
    // probe-count baseline the jump path is measured against.
    let t0 = Instant::now();
    let (pairs_sfc_dec, ssd) = join_sfc_decompose_dims(&points, eps, index_dims);
    let sfc_dec_dt = t0.elapsed();

    // The serving-layer path: grouped stencil key plans routed across
    // the store's shard fenceposts on one snapshot.
    let t0 = Instant::now();
    let (pairs_store, sst) = join_store_dims(&points, eps, index_dims);
    let store_dt = t0.elapsed();

    // Its baseline: one window decomposition through the planner per
    // point.
    let t0 = Instant::now();
    let (pairs_store_dec, sstd) = join_store_decompose_dims(&points, eps, index_dims);
    let store_dec_dt = t0.elapsed();

    assert_eq!(pairs_2d.len(), pairs_grid.len(), "identical result pair sets");
    assert_eq!(pairs_grid.len(), pairs_fgf.len(), "identical result pair sets");
    assert_eq!(pairs_fgf.len(), pairs_sfc.len(), "identical result pair sets");
    assert_eq!(pairs_sfc.len(), pairs_store.len(), "identical result pair sets");
    // Jump-vs-decompose parity: same pairs, same candidate structure,
    // same distance computations — only the probe count may differ.
    assert_eq!(pairs_sfc, pairs_sfc_dec, "jump join must equal decomposition bit for bit");
    assert_eq!(ss.cell_pairs, ssd.cell_pairs, "identical candidate cell pairs");
    assert_eq!(ss.comparisons, ssd.comparisons, "identical distance computations");
    assert_eq!(
        {
            let mut p = pairs_store.clone();
            p.sort_unstable();
            p
        },
        {
            let mut p = pairs_store_dec.clone();
            p.sort_unstable();
            p
        },
        "store jump join must equal decomposition"
    );
    assert_eq!(sst.comparisons, sstd.comparisons, "identical distance computations (store)");
    println!(
        "simjoin n={n} d={d} eps={eps}: {} pairs (all variants identical)",
        pairs_sfc.len()
    );
    let mut t = Table::new(vec![
        "variant",
        "index dims",
        "ms",
        "cell pairs",
        "comparisons",
        "ranges",
        "key probes",
        "jumps",
    ]);
    for (name, dims, dt, s) in [
        ("sfc-neighbor-nd (default)", index_dims, sfc_dt, &ss),
        ("sfc-decompose-nd (baseline)", index_dims, sfc_dec_dt, &ssd),
        ("sfc-store-neighbor (serving)", index_dims, store_dt, &sst),
        ("sfc-store-decompose (baseline)", index_dims, store_dec_dt, &sstd),
        ("grid-2d-projection", 2, proj_dt, &s2),
        ("grid-nd", index_dims, grid_dt, &sg),
        ("fgf-hilbert-nd", index_dims, fgf_dt, &sf),
    ] {
        t.row(vec![
            name.to_string(),
            dims.to_string(),
            format!("{:.1}", dt.as_secs_f64() * 1e3),
            s.cell_pairs.to_string(),
            s.comparisons.to_string(),
            s.ranges.to_string(),
            s.key_probes.to_string(),
            s.fgf.map(|f| f.jumps).unwrap_or(0).to_string(),
        ]);
    }
    print!("{}", t.render());
    if index_dims <= 8 {
        assert!(
            ss.key_probes < ssd.key_probes,
            "stencil jumps must probe less than decomposition ({} vs {})",
            ss.key_probes,
            ssd.key_probes
        );
        assert!(
            sst.key_probes < sstd.key_probes,
            "store stencil plans must probe less than per-point decomposition ({} vs {})",
            sst.key_probes,
            sstd.key_probes
        );
        println!(
            "neighbor jumps: {:.2}x fewer key probes than decomposition (index), \
             {:.2}x fewer (store)",
            ssd.key_probes as f64 / ss.key_probes.max(1) as f64,
            sstd.key_probes as f64 / sst.key_probes.max(1) as f64,
        );
    }
    if index_dims > 2 {
        println!(
            "d-dim pruning: {} distance computations vs {} with the 2-D projection ({:.2}x fewer)",
            sg.comparisons,
            s2.comparisons,
            s2.comparisons as f64 / sg.comparisons.max(1) as f64,
        );
    }
}

/// The `query` subcommand: build an order-sorted [`SfcIndex`] over a
/// clustered synthetic workload and report per-curve query statistics —
/// ranges-per-query (the clustering property made measurable),
/// selectivity, the exact-filter ratio, and a decomposition-vs-scan
/// comparison.
fn query_cmd(args: &Args) {
    let n: usize = args.get("n", 20_000);
    let d: usize = args.get("dims", 2);
    let level: u32 = args.get("level", 8);
    let queries: usize = args.get("queries", 200).max(1);
    let max_ranges: usize = args.get("max-ranges", 0);
    let k: usize = args.get("k", 10);
    let frac: f32 = args.get("window-frac", 0.05);
    let threads: usize = args.get("threads", 0);
    let mode = args.get_str("mode", "window");
    let curve: CurveKind = match args.get_str("curve", "hilbert").parse() {
        Ok(c) => c,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };
    let points = make_clustered(n, d, 40, 0.8, 7);
    let (min, max) =
        sfc_mine::index::axis_bounds(&points, d).expect("workload is non-empty");
    let mut rng = Rng::new(1234);
    match mode.as_str() {
        "window" => {
            // Centered on random data rows so selectivity stays non-trivial.
            let windows: Vec<(Vec<f32>, Vec<f32>)> = (0..queries)
                .map(|_| {
                    let p = rng.below_usize(n);
                    let lo: Vec<f32> = (0..d)
                        .map(|a| points.at(p, a) - frac * (max[a] - min[a]))
                        .collect();
                    let hi: Vec<f32> = (0..d)
                        .map(|a| points.at(p, a) + frac * (max[a] - min[a]))
                        .collect();
                    (lo, hi)
                })
                .collect();
            // Full-scan baseline: one pass over all rows per query.
            let t0 = Instant::now();
            let mut scan_results = 0u64;
            for (lo, hi) in &windows {
                for p in 0..n {
                    let row = points.row(p);
                    if row
                        .iter()
                        .zip(lo.iter().zip(hi))
                        .all(|(&v, (&l, &h))| (l..=h).contains(&v))
                    {
                        scan_results += 1;
                    }
                }
            }
            let scan_dt = t0.elapsed();
            let mut t = Table::new(vec![
                "variant",
                "build ms",
                "ms/query",
                "ranges/query",
                "cands/query",
                "filter %",
                "selectivity %",
            ]);
            let mut curves = vec![CurveKind::Hilbert, CurveKind::ZOrder, CurveKind::Canonic];
            if !curves.contains(&curve) {
                curves.insert(0, curve);
            }
            // Kept for par_query below, so the chosen curve's index is
            // not built twice.
            let mut chosen_index: Option<SfcIndex> = None;
            for kind in curves {
                let t0 = Instant::now();
                let index = SfcIndex::build_with(&points, level, kind);
                let build_dt = t0.elapsed();
                let t0 = Instant::now();
                let (mut ranges, mut cands, mut results) = (0u64, 0u64, 0u64);
                for (lo, hi) in &windows {
                    let (_, s) = index.query_window_stats(lo, hi, max_ranges);
                    ranges += s.ranges as u64;
                    cands += s.candidates;
                    results += s.results;
                }
                let dt = t0.elapsed();
                assert_eq!(results, scan_results, "index results must equal the scan");
                t.row(vec![
                    format!("sfc-index/{}", kind.name()),
                    format!("{:.1}", build_dt.as_secs_f64() * 1e3),
                    format!("{:.3}", dt.as_secs_f64() * 1e3 / queries as f64),
                    format!("{:.1}", ranges as f64 / queries as f64),
                    format!("{:.1}", cands as f64 / queries as f64),
                    format!("{:.1}", 100.0 * results as f64 / cands.max(1) as f64),
                    format!("{:.2}", 100.0 * results as f64 / (n as u64 * queries as u64) as f64),
                ]);
                if kind == curve {
                    chosen_index = Some(index);
                }
            }
            t.row(vec![
                "full-scan".to_string(),
                "-".to_string(),
                format!("{:.3}", scan_dt.as_secs_f64() * 1e3 / queries as f64),
                "-".to_string(),
                n.to_string(),
                format!("{:.1}", 100.0 * scan_results as f64 / (n as u64 * queries as u64) as f64),
                format!("{:.2}", 100.0 * scan_results as f64 / (n as u64 * queries as u64) as f64),
            ]);
            println!(
                "window queries: n={n} d={d} level={level} queries={queries} \
                 window-frac={frac} max-ranges={max_ranges}"
            );
            print!("{}", t.render());
            if threads > 0 {
                let index = chosen_index.expect("chosen curve is always in the table");
                let coord = Coordinator::new(threads);
                let t0 = Instant::now();
                let out = coord.par_query(&index, &windows);
                let dt = t0.elapsed();
                let total: usize = out.iter().map(Vec::len).sum();
                println!(
                    "par_query [{}]: {} workers, {:.3} ms/query ({total} results)",
                    curve.name(),
                    coord.threads(),
                    dt.as_secs_f64() * 1e3 / queries as f64,
                );
            }
        }
        "point" => {
            let index = SfcIndex::build_with(&points, level, curve);
            let ids: Vec<usize> = (0..queries).map(|_| rng.below_usize(n)).collect();
            let t0 = Instant::now();
            let mut found = 0u64;
            for &p in &ids {
                found += index.query_point(points.row(p)).len() as u64;
            }
            let dt = t0.elapsed();
            let t0 = Instant::now();
            let mut scan_found = 0u64;
            for &p in &ids {
                let q = points.row(p);
                scan_found += (0..n).filter(|&r| points.row(r) == q).count() as u64;
            }
            let scan_dt = t0.elapsed();
            assert_eq!(found, scan_found, "point hits must equal the scan");
            println!(
                "point queries [{}]: n={n} d={d} level={level} queries={queries}: \
                 {found} hits, {:.4} ms/query (scan {:.3} ms/query)",
                curve.name(),
                dt.as_secs_f64() * 1e3 / queries as f64,
                scan_dt.as_secs_f64() * 1e3 / queries as f64,
            );
        }
        "knn" => {
            let index = SfcIndex::build_with(&points, level, curve);
            let centers: Vec<Vec<f32>> = (0..queries)
                .map(|_| {
                    let p = rng.below_usize(n);
                    (0..d)
                        .map(|a| points.at(p, a) + (rng.f32() - 0.5) * (max[a] - min[a]) * 0.1)
                        .collect()
                })
                .collect();
            let t0 = Instant::now();
            let mut dist_sum = 0f64;
            let mut probes = 0u64;
            let mut all_hits = Vec::with_capacity(queries);
            for q in &centers {
                let (hits, s) = index.query_knn_stats(q, k);
                probes += s.key_probes;
                for &(_, dist) in &hits {
                    dist_sum += dist as f64;
                }
                all_hits.push(hits);
            }
            let dt = t0.elapsed();
            // The retired expanding-window driver: parity baseline for
            // the frontier search (bit-for-bit identical results).
            let t0 = Instant::now();
            let mut legacy_probes = 0u64;
            for (q, hits) in centers.iter().zip(&all_hits) {
                let (legacy, s) = index.query_knn_legacy_stats(q, k);
                legacy_probes += s.key_probes;
                assert_eq!(&legacy, hits, "frontier must equal legacy bit for bit");
            }
            let legacy_dt = t0.elapsed();
            println!(
                "kNN driver [{}]: neighbor path {}, {:.1} key probes/query \
                 (legacy expanding-window: {:.1})",
                curve.name(),
                index.neighbor_path().name(),
                probes as f64 / queries as f64,
                legacy_probes as f64 / queries as f64,
            );
            println!(
                "kNN legacy driver: {:.3} ms/query",
                legacy_dt.as_secs_f64() * 1e3 / queries as f64
            );
            let t0 = Instant::now();
            let mut scan_sum = 0f64;
            for q in &centers {
                let mut best: Vec<f32> = (0..n)
                    .map(|p| {
                        points
                            .row(p)
                            .iter()
                            .zip(q)
                            .map(|(&a, &b)| (a - b) * (a - b))
                            .sum::<f32>()
                            .sqrt()
                    })
                    .collect();
                best.sort_by(f32::total_cmp);
                scan_sum += best.iter().take(k).map(|&x| x as f64).sum::<f64>();
            }
            let scan_dt = t0.elapsed();
            assert!(
                (dist_sum - scan_sum).abs() < 1e-3 * scan_sum.abs().max(1.0),
                "kNN distances must match the scan ({dist_sum} vs {scan_sum})"
            );
            println!(
                "kNN queries [{}]: n={n} d={d} level={level} k={k} queries={queries}: \
                 {:.3} ms/query (scan {:.3} ms/query)",
                curve.name(),
                dt.as_secs_f64() * 1e3 / queries as f64,
                scan_dt.as_secs_f64() * 1e3 / queries as f64,
            );
        }
        other => {
            eprintln!("unknown query mode '{other}' (point|window|knn)");
            std::process::exit(2);
        }
    }
}

/// The `store` subcommand: drive the sharded, mutable [`SfcStore`]
/// through (1) a bulk ingest, (2) a mixed insert/delete/query workload
/// on snapshot reads, (3) a full compaction plus an equi-depth
/// rebalance — fanned across `--maintenance-threads` workers when set
/// (`par_compact`/`par_rebalance`, byte-identical to serial) — then
/// (4) verify **recall 1.0** against a freshly rebuilt `SfcIndex` over
/// the live set and report batched snapshot-query scaling across
/// worker counts.
fn store_cmd(args: &Args) {
    use sfc_mine::index::{SfcStore, StoreConfig, SyncPolicy};

    let n: usize = args.get("n", 20_000);
    let d: usize = args.get("dims", 3);
    let level: u32 = args.get("level", 8);
    let shards: usize = args.get("shards", 8);
    let batch: usize = args.get("batch", 512).max(1);
    let buffer: usize = args.get("buffer-rows", 256);
    let ops: usize = args.get("ops", 20_000);
    let delete_frac: f32 = args.get("delete-frac", 0.2);
    let query_frac: f32 = args.get("query-frac", 0.3);
    let frac: f32 = args.get("window-frac", 0.05);
    let queries: usize = args.get("queries", 200).max(1);
    let threads: usize = args.get("threads", 0);
    let curve: CurveKind = match args.get_str("curve", "hilbert").parse() {
        Ok(c) => c,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };
    let dir = args.get_str("dir", "");
    let sync: SyncPolicy = match args.get_str("sync", "always").parse() {
        Ok(s) => s,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };
    // `--dir` pointing at an existing store: reopen-and-verify mode (the
    // crash-recovery path — used by the CI recovery-smoke job after a
    // SIGKILL mid-ingest).
    if !dir.is_empty() && std::path::Path::new(&dir).join("CURRENT").exists() {
        store_reopen_cmd(&dir, queries, frac);
        return;
    }
    let points = make_clustered(n, d, 40, 0.8, 7);
    let (min, max) = sfc_mine::index::axis_bounds(&points, d).expect("workload is non-empty");
    let mut rng = Rng::new(99);
    let mut t = Table::new(vec!["phase", "ops", "ms", "ops/s or ms/query", "notes"]);

    // ---- phase 1: bulk ingest ------------------------------------------
    let cfg = StoreConfig { shards, buffer_rows: buffer };
    let t0 = Instant::now();
    let store = if dir.is_empty() {
        SfcStore::from_points(&points, level, curve, cfg)
    } else {
        // Durable: create under `--dir`, ingest through the WAL, then
        // re-cut the fenceposts equi-depth like `from_points` does.
        let store =
            match SfcStore::create(&dir, d, level, curve, min.clone(), &max, cfg, sync) {
                Ok(s) => s,
                Err(e) => {
                    eprintln!("store: cannot create {dir}: {e}");
                    std::process::exit(2);
                }
            };
        store.insert_batch(&points);
        store.rebalance();
        store
    };
    let ingest_dt = t0.elapsed();
    let snap = store.snapshot();
    t.row(vec![
        "bulk ingest".into(),
        n.to_string(),
        fmt_ms(ingest_dt),
        format!("{:.0} pts/s", n as f64 / ingest_dt.as_secs_f64()),
        format!(
            "{} shards, {} segments",
            store.shard_count(),
            snap.shard_segment_counts().iter().sum::<usize>()
        ),
    ]);

    // Live bookkeeping for the mixed phase (deletes need the row).
    let mut live: Vec<(u32, Vec<f32>)> =
        (0..n).map(|p| (p as u32, points.row(p).to_vec())).collect();
    let random_window = |center: &[f32]| {
        let lo: Vec<f32> = (0..d).map(|a| center[a] - frac * (max[a] - min[a])).collect();
        let hi: Vec<f32> = (0..d).map(|a| center[a] + frac * (max[a] - min[a])).collect();
        (lo, hi)
    };

    // ---- phase 2: mixed insert/delete/query ----------------------------
    let (mut n_ins, mut n_del, mut n_q) = (0u64, 0u64, 0u64);
    let mut q_lat = LatencyHistogram::new();
    let mut agg = sfc_mine::index::QueryStats::default();
    let mut batch_rows = Matrix::zeros(0, d);
    let t0 = Instant::now();
    for _ in 0..ops {
        let r = rng.f32();
        if r < delete_frac && !live.is_empty() {
            let v = rng.below_usize(live.len());
            let (id, row) = live.swap_remove(v);
            store.delete(id, &row);
            n_del += 1;
        } else if r < delete_frac + query_frac && !live.is_empty() {
            let c = rng.below_usize(live.len());
            let (lo, hi) = random_window(&live[c].1.clone());
            let tq = Instant::now();
            let (_, s) = store.query_window_stats(&lo, &hi, 0);
            q_lat.record_duration(tq.elapsed());
            agg.ranges += s.ranges;
            agg.candidates += s.candidates;
            agg.results += s.results;
            agg.shards_touched += s.shards_touched;
            agg.segments_probed += s.segments_probed;
            n_q += 1;
        } else {
            let src = rng.below_usize(n);
            let row: Vec<f32> = (0..d)
                .map(|a| points.at(src, a) + (rng.f32() - 0.5) * (max[a] - min[a]) * 0.02)
                .collect();
            batch_rows.data.extend_from_slice(&row);
            batch_rows.rows += 1;
            if batch_rows.rows >= batch {
                let first = store.insert_batch(&batch_rows);
                for i in 0..batch_rows.rows {
                    live.push((first + i as u32, batch_rows.row(i).to_vec()));
                }
                batch_rows = Matrix::zeros(0, d);
            }
            n_ins += 1;
        }
    }
    if batch_rows.rows > 0 {
        let first = store.insert_batch(&batch_rows);
        for i in 0..batch_rows.rows {
            live.push((first + i as u32, batch_rows.row(i).to_vec()));
        }
    }
    let mixed_dt = t0.elapsed();
    t.row(vec![
        "mixed workload".into(),
        ops.to_string(),
        fmt_ms(mixed_dt),
        format!("{:.0} ops/s", ops as f64 / mixed_dt.as_secs_f64()),
        format!("{n_ins} ins / {n_del} del / {n_q} qry"),
    ]);
    if n_q > 0 {
        t.row(vec![
            "  window queries".into(),
            n_q.to_string(),
            "-".into(),
            q_lat.summary(),
            format!(
                "{:.1} shards, {:.1} segs, {:.1} ranges/query, filter {:.0}%",
                agg.shards_touched as f64 / n_q as f64,
                agg.segments_probed as f64 / n_q as f64,
                agg.ranges as f64 / n_q as f64,
                100.0 * agg.filter_ratio(),
            ),
        ]);
    }

    // ---- phase 3: maintenance (compact + rebalance) --------------------
    let mtn: usize = args.get("maintenance-threads", 0);
    let before = store.snapshot().entries();
    let fan_in: usize = store.snapshot().shard_segment_counts().iter().sum();
    let t0 = Instant::now();
    if mtn > 0 {
        store.par_compact(&Coordinator::new(mtn));
    } else {
        store.compact();
    }
    let compact_dt = t0.elapsed();
    let after = store.snapshot().entries();
    t.row(vec![
        if mtn > 0 { format!("compact x{mtn}") } else { "compact".into() },
        "-".into(),
        fmt_ms(compact_dt),
        format!("{:.0} rows/s", before as f64 / compact_dt.as_secs_f64()),
        format!(
            "{before} -> {after} entries, fan-in {fan_in} segs, {} shards{}",
            store.shard_count(),
            if mtn > 0 { " in parallel" } else { "" },
        ),
    ]);
    let t0 = Instant::now();
    if mtn > 0 {
        store.par_rebalance(&Coordinator::new(mtn));
    } else {
        store.rebalance();
    }
    let reb_dt = t0.elapsed();
    let reb_entries = store.snapshot().entries();
    t.row(vec![
        if mtn > 0 { format!("rebalance x{mtn}") } else { "rebalance".into() },
        "-".into(),
        fmt_ms(reb_dt),
        format!("{:.0} rows/s", reb_entries as f64 / reb_dt.as_secs_f64()),
        format!("{} shards re-cut equi-depth", store.shard_count()),
    ]);

    // ---- phase 4: recall vs a fresh SfcIndex on the live set -----------
    let snap = store.snapshot();
    let (live_ids, live_rows) = store.collect_live(&snap);
    assert_eq!(live_ids.len(), live.len(), "live bookkeeping must agree");
    if live_rows.rows == 0 {
        println!("store: every point deleted — nothing to recall-check");
        print!("{}", t.render());
        return;
    }
    let t0 = Instant::now();
    let index = SfcIndex::build_with(&live_rows, level, curve);
    let rebuild_dt = t0.elapsed();
    let mut matched = 0u64;
    let mut expected = 0u64;
    let windows: Vec<(Vec<f32>, Vec<f32>)> = (0..queries)
        .map(|_| {
            let c = rng.below_usize(live_rows.rows.max(1));
            random_window(live_rows.row(c))
        })
        .collect();
    for (lo, hi) in &windows {
        let mut got = store.query_window_on(&snap, lo, hi);
        // Index ids are positions into live_rows; map to store ids.
        let mut want: Vec<u32> =
            index.query_window(lo, hi).iter().map(|&i| live_ids[i as usize]).collect();
        got.sort_unstable();
        want.sort_unstable();
        expected += want.len() as u64;
        matched += got.iter().filter(|id| want.binary_search(id).is_ok()).count() as u64;
        assert_eq!(got, want, "store must return exactly the rebuilt index's rows");
    }
    t.row(vec![
        "recall check".into(),
        queries.to_string(),
        fmt_ms(rebuild_dt),
        format!("recall {:.3}", if expected == 0 { 1.0 } else { matched as f64 / expected as f64 }),
        format!("vs fresh SfcIndex rebuild over {} live pts", live_ids.len()),
    ]);

    // ---- phase 5: snapshot-query thread scaling ------------------------
    let thread_sweep: Vec<usize> = if threads > 0 { vec![threads] } else { vec![1, 2, 4, 8] };
    for tn in thread_sweep {
        let coord = Coordinator::new(tn);
        let t0 = Instant::now();
        let out = coord.par_query_store(&store, &windows);
        let dt = t0.elapsed();
        let total: usize = out.iter().map(Vec::len).sum();
        t.row(vec![
            format!("par_query_store x{tn}"),
            windows.len().to_string(),
            fmt_ms(dt),
            format!("{:.3} ms/query", dt.as_secs_f64() * 1e3 / windows.len() as f64),
            format!("{total} results"),
        ]);
    }

    println!(
        "store [{}]: n={n} d={d} level={level} shards={shards} buffer={buffer} \
         ops={ops} (del {delete_frac} / qry {query_frac}){}",
        curve.name(),
        if dir.is_empty() { String::new() } else { format!(" dir={dir} sync={sync:?}") },
    );
    print!("{}", t.render());

    // ---- phase 6 (durable only): close, cold-reopen, verify ------------
    if !dir.is_empty() {
        store.close().expect("store close");
        let t0 = Instant::now();
        let reopened = SfcStore::open_with(&dir, sync).expect("store reopen");
        let open_dt = t0.elapsed();
        let (rids, _) = reopened.collect_live(&reopened.snapshot());
        assert_eq!(rids.len(), live_ids.len(), "reopened live set size");
        for (lo, hi) in windows.iter().take(50) {
            let mut got = reopened.query_window(lo, hi);
            let mut want: Vec<u32> =
                index.query_window(lo, hi).iter().map(|&i| live_ids[i as usize]).collect();
            got.sort_unstable();
            want.sort_unstable();
            assert_eq!(got, want, "reopened store must match the fresh index");
        }
        println!(
            "recovered {} rows, parity OK (cold open {}, {} windows verified)",
            rids.len(),
            fmt_ms(open_dt),
            windows.len().min(50),
        );
    }
}

/// Reopen-only mode of the `store` subcommand: `--dir` points at an
/// existing store (for example after a kill mid-ingest). Replays the
/// WAL, rebuilds the snapshot, verifies query parity against a fresh
/// `SfcIndex` over the recovered live set, and prints the
/// `recovered N rows, parity OK` line the CI recovery-smoke job greps.
fn store_reopen_cmd(dir: &str, queries: usize, frac: f32) {
    use sfc_mine::index::SfcStore;

    let t0 = Instant::now();
    let store = match SfcStore::open(dir) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("store: cannot open {dir}: {e}");
            std::process::exit(2);
        }
    };
    let open_dt = t0.elapsed();
    let snap = store.snapshot();
    let (live_ids, live_rows) = store.collect_live(&snap);
    println!(
        "store [{}]: reopened {dir} (d={}, level={}, {} shards, {} entries)",
        store.curve().name(),
        store.dims(),
        store.level(),
        store.shard_count(),
        snap.entries(),
    );
    if live_rows.rows == 0 {
        println!("recovered 0 rows, parity OK (store is empty)");
        return;
    }
    let d = store.dims();
    let index = SfcIndex::build_with(&live_rows, store.level(), store.curve());
    let (min, max) = sfc_mine::index::axis_bounds(&live_rows, d).expect("live set is non-empty");
    let mut rng = Rng::new(7);
    let nq = queries.max(1);
    for _ in 0..nq {
        let c = rng.below_usize(live_rows.rows);
        let lo: Vec<f32> =
            (0..d).map(|a| live_rows.at(c, a) - frac * (max[a] - min[a])).collect();
        let hi: Vec<f32> =
            (0..d).map(|a| live_rows.at(c, a) + frac * (max[a] - min[a])).collect();
        let mut got = store.query_window_on(&snap, &lo, &hi);
        let mut want: Vec<u32> =
            index.query_window(&lo, &hi).iter().map(|&i| live_ids[i as usize]).collect();
        got.sort_unstable();
        want.sort_unstable();
        assert_eq!(got, want, "recovered store must match a fresh index");
    }
    println!(
        "recovered {} rows, parity OK (cold open {}, {nq} window queries verified)",
        live_ids.len(),
        fmt_ms(open_dt),
    );
}

/// One churn producer's query-latency record (merged after the run).
#[derive(Default)]
struct ChurnLat {
    window: LatencyHistogram,
    knn: LatencyHistogram,
    point: LatencyHistogram,
    ops: u64,
    rows: u64,
}

/// The `serve` subcommand: run the full serving pipeline — async
/// backpressured ingestion ([`sfc_mine::index::IngestPipeline`]),
/// background maintenance workers, and the replicated query tier
/// ([`sfc_mine::index::QueryRouter`]) — under a sustained mixed
/// insert/delete/window/kNN/point workload at a target QPS, then drain
/// to quiescence and assert bit-for-bit query parity against a fresh
/// [`SfcIndex`] over the live set. `--scenario trajectory` switches to
/// (x, y, t) points with time as the third curve dimension and expires
/// a sliding time window via range deletes through the pipeline.
fn serve_cmd(args: &Args) {
    use sfc_mine::index::{IngestPipeline, PipelineConfig, QueryRouter, SfcStore, StoreConfig};
    use std::sync::Arc;
    use std::time::Duration;

    let scenario = args.get_str("scenario", "uniform");
    let trajectory = match scenario.as_str() {
        "uniform" => false,
        "trajectory" => true,
        other => {
            eprintln!("unknown scenario '{other}' (uniform|trajectory)");
            std::process::exit(2);
        }
    };
    let n: usize = args.get("n", 100_000);
    let d: usize = if trajectory { 3 } else { args.get("dims", 3) };
    let level: u32 = args.get("level", 8);
    let shards: usize = args.get("shards", 8);
    let buffer: usize = args.get("buffer-rows", 256);
    let curve: CurveKind = match args.get_str("curve", "hilbert").parse() {
        Ok(c) => c,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };
    let qps: u64 = args.get("qps", 20_000).max(1);
    let seconds: f64 = args.get("seconds", 5.0);
    let producers: usize = args.get("producers", 4).max(1);
    let replicas: usize = args.get("replicas", 3).max(1);
    let inflight: usize = args.get("inflight", 4).max(1);
    let mtn: usize = args.get("maintenance-threads", 2);
    let rows_per_insert: usize = args.get("rows-per-insert", 8).max(1);
    let frac: f32 = args.get("window-frac", 0.03);
    let k: usize = args.get("k", 8).max(1);
    let queries: usize = args.get("queries", 300).max(10);
    let expire_window: f32 = args.get("expire-window", 1.0);
    let cfg = PipelineConfig {
        queue_rows: args.get("queue-rows", 4096),
        batch_rows: args.get("batch-rows", 512),
        batch_wait: Duration::from_micros(args.get("batch-wait-us", 200)),
        maintenance_threads: mtn,
        compact_segments: args.get("compact-segments", 12),
        ..PipelineConfig::default()
    };

    // ---- build: initial point set + store + router ---------------------
    let spatial = make_clustered(n, if trajectory { 2 } else { d }, 40, 0.8, 7);
    let mut rng = Rng::new(42);
    let points = if trajectory {
        // (x, y, t): initial timestamps fill one expiry window.
        Matrix::from_fn(n, 3, |i, j| {
            if j < 2 {
                spatial.at(i, j)
            } else {
                (i as f32 / n.max(1) as f32 - 1.0) * expire_window
            }
        })
    } else {
        spatial.clone()
    };
    let (min, max) = sfc_mine::index::axis_bounds(&points, d).expect("workload is non-empty");
    let t0 = Instant::now();
    let store = if trajectory {
        // Size the t axis for the whole run up front so later
        // timestamps keep their own cells instead of clamping.
        let mut hi = max.clone();
        hi[2] = seconds as f32 + expire_window;
        let s = SfcStore::new(
            d,
            level,
            curve,
            min.clone(),
            &hi,
            StoreConfig { shards, buffer_rows: buffer },
        );
        s.insert_batch(&points);
        s.rebalance();
        Arc::new(s)
    } else {
        Arc::new(SfcStore::from_points(
            &points,
            level,
            curve,
            StoreConfig { shards, buffer_rows: buffer },
        ))
    };
    let build_dt = t0.elapsed();
    let router = Arc::new(QueryRouter::new(Arc::clone(&store), replicas, inflight));
    let random_window = |center: &[f32]| {
        let lo: Vec<f32> = (0..d).map(|a| center[a] - frac * (max[a] - min[a])).collect();
        let hi: Vec<f32> = (0..d).map(|a| center[a] + frac * (max[a] - min[a])).collect();
        (lo, hi)
    };

    // ---- quiescent baseline: same queries, no churn --------------------
    router.refresh();
    let mut quiet = LatencyHistogram::new();
    for i in 0..queries {
        let c = rng.below_usize(n);
        let center = points.row(c).to_vec();
        let tq = Instant::now();
        match i % 3 {
            0 => drop(router.query_knn(&center, k)),
            1 => drop(router.query_point(&center)),
            _ => {
                let (lo, hi) = random_window(&center);
                drop(router.query_window(&lo, &hi));
            }
        }
        quiet.record_duration(tq.elapsed());
    }

    // ---- churn: producers at a target QPS through the pipeline ---------
    let pipeline =
        IngestPipeline::with_router(Arc::clone(&store), cfg, Some(Arc::clone(&router)));
    let total_ops = (qps as f64 * seconds) as u64;
    let interval =
        Duration::from_nanos((1e9 * producers as f64 / qps as f64).max(1.0) as u64);
    let churn_t0 = Instant::now();
    let deadline = churn_t0 + Duration::from_secs_f64(seconds);
    let lats: Vec<ChurnLat> = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for p in 0..producers {
            let my_ops = total_ops / producers as u64
                + u64::from((p as u64) < total_ops % producers as u64);
            let pipeline = &pipeline;
            let router = &router;
            let points = &points;
            let min = &min;
            let max = &max;
            handles.push(scope.spawn(move || {
                let mut rng = Rng::new(1000 + p as u64);
                let mut out = ChurnLat::default();
                let mut mine: Vec<(u32, Vec<f32>)> = Vec::new();
                let mut next = Instant::now();
                for _ in 0..my_ops {
                    let now = Instant::now();
                    if now < next {
                        std::thread::sleep(next - now);
                    }
                    next += interval;
                    let src = rng.below_usize(n);
                    let mut row: Vec<f32> = (0..d)
                        .map(|a| {
                            points.at(src, a)
                                + (rng.f32() - 0.5) * (max[a] - min[a]) * 0.02
                        })
                        .collect();
                    if trajectory {
                        row[2] = churn_t0.elapsed().as_secs_f32();
                    }
                    let r = rng.f32();
                    let (ins_f, del_f, win_f, knn_f) = if trajectory {
                        (0.55, 0.05, 0.20, 0.10)
                    } else {
                        (0.40, 0.10, 0.30, 0.10)
                    };
                    if r < ins_f {
                        let rows = Matrix::from_fn(rows_per_insert, d, |i, j| {
                            if trajectory && j == 2 {
                                row[2]
                            } else {
                                row[j] + i as f32 * 1e-4
                            }
                        });
                        let first = pipeline.submit_insert(rows.clone());
                        if mine.len() < 4096 {
                            mine.push((first, rows.row(0).to_vec()));
                        }
                        out.rows += rows_per_insert as u64;
                    } else if r < ins_f + del_f {
                        if let Some(last) = mine.pop() {
                            let m = Matrix { rows: 1, cols: d, data: last.1 };
                            pipeline.submit_delete(&[last.0], &m);
                            out.rows += 1;
                        }
                    } else if r < ins_f + del_f + win_f {
                        let (lo, hi) = {
                            let lo: Vec<f32> = (0..d)
                                .map(|a| row[a] - frac * (max[a] - min[a]))
                                .collect();
                            let hi: Vec<f32> = (0..d)
                                .map(|a| row[a] + frac * (max[a] - min[a]))
                                .collect();
                            (lo, hi)
                        };
                        let tq = Instant::now();
                        drop(router.query_window(&lo, &hi));
                        out.window.record_duration(tq.elapsed());
                    } else if r < ins_f + del_f + win_f + knn_f {
                        let tq = Instant::now();
                        drop(router.query_knn(&row, k));
                        out.knn.record_duration(tq.elapsed());
                    } else {
                        let tq = Instant::now();
                        drop(router.query_point(&row));
                        out.point.record_duration(tq.elapsed());
                    }
                    out.ops += 1;
                }
                out
            }));
        }
        if trajectory {
            // Expiry clock: slide the time window via range deletes.
            let pipeline = &pipeline;
            let min = &min;
            let max = &max;
            handles.push(scope.spawn(move || {
                let mut out = ChurnLat::default();
                loop {
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    std::thread::sleep(Duration::from_millis(200).min(deadline - now));
                    let cutoff = churn_t0.elapsed().as_secs_f32() - expire_window;
                    let lo = vec![min[0] - 1.0, min[1] - 1.0, -expire_window - 1.0];
                    let hi = vec![max[0] + 1.0, max[1] + 1.0, cutoff];
                    if cutoff > -expire_window {
                        pipeline.submit_expire(&lo, &hi);
                        out.ops += 1;
                    }
                }
                out
            }));
        }
        handles.into_iter().map(|h| h.join().expect("producer thread panicked")).collect()
    });
    let churn_dt = churn_t0.elapsed();
    pipeline.drain().expect("pipeline drain");
    pipeline.settle_maintenance();
    router.refresh();

    // ---- quiescent again (post-churn), then parity ---------------------
    let mut quiet_after = LatencyHistogram::new();
    let snap = store.snapshot();
    let (live_ids, live_rows) = store.collect_live(&snap);
    for _ in 0..queries.min(100) {
        if live_rows.rows == 0 {
            break;
        }
        let c = rng.below_usize(live_rows.rows);
        let (lo, hi) = random_window(live_rows.row(c));
        let tq = Instant::now();
        drop(router.query_window(&lo, &hi));
        quiet_after.record_duration(tq.elapsed());
    }

    let mut churn_all = LatencyHistogram::new();
    let (mut wh, mut kh, mut ph) = (
        LatencyHistogram::new(),
        LatencyHistogram::new(),
        LatencyHistogram::new(),
    );
    let (mut ops_done, mut rows_done) = (0u64, 0u64);
    for l in &lats {
        churn_all.merge(&l.window);
        churn_all.merge(&l.knn);
        churn_all.merge(&l.point);
        wh.merge(&l.window);
        kh.merge(&l.knn);
        ph.merge(&l.point);
        ops_done += l.ops;
        rows_done += l.rows;
    }
    let stats = pipeline.stats();
    let rstats = router.stats();
    let dstats = store.durability_stats();

    let mut t = Table::new(vec!["measure", "value", "notes"]);
    t.row(vec![
        "bulk build".into(),
        fmt_ms(build_dt),
        format!("{n} pts, {} shards, {} replicas", shards, replicas),
    ]);
    t.row(vec![
        "churn ops".into(),
        ops_done.to_string(),
        format!(
            "{:.0} ops/s achieved (target {qps}), {:.1} s",
            ops_done as f64 / churn_dt.as_secs_f64(),
            churn_dt.as_secs_f64(),
        ),
    ]);
    t.row(vec![
        "ingest".into(),
        format!("{} rows", stats.applied_rows),
        format!(
            "{:.0} rows/s, {} batches, mean {:.1} rows/batch, max {}",
            stats.applied_rows as f64 / churn_dt.as_secs_f64(),
            stats.batches,
            stats.applied_rows as f64 / stats.batches.max(1) as f64,
            stats.max_batch_rows,
        ),
    ]);
    t.row(vec![
        "queue".into(),
        format!("{} / {} rows max", stats.max_queue_rows, cfg.queue_rows),
        format!(
            "{} blocked, {} shed, {} paced stalls",
            stats.blocked_producers, stats.shed_ops, stats.paced_stalls,
        ),
    ]);
    t.row(vec![
        "maintenance".into(),
        format!("x{mtn} threads"),
        format!(
            "{} flush / {} compact / {} rebalance passes",
            stats.flushes, stats.compactions, stats.rebalances,
        ),
    ]);
    if stats.expired_rows > 0 {
        t.row(vec![
            "expiry".into(),
            format!("{} rows", stats.expired_rows),
            "sliding-window range deletes".into(),
        ]);
    }
    for (name, h) in [("window", &wh), ("knn", &kh), ("point", &ph)] {
        if h.count() > 0 {
            t.row(vec![
                format!("{name} latency (churn)"),
                h.summary(),
                format!("{} queries", h.count()),
            ]);
        }
    }
    t.row(vec!["all queries (churn)".into(), churn_all.summary(), String::new()]);
    t.row(vec![
        "quiescent before".into(),
        quiet.summary(),
        format!("{} queries", quiet.count()),
    ]);
    t.row(vec![
        "quiescent after".into(),
        quiet_after.summary(),
        format!("{} queries", quiet_after.count()),
    ]);
    let served: Vec<String> = rstats
        .replicas
        .iter()
        .map(|r| format!("{}({})", r.served, r.max_inflight))
        .collect();
    t.row(vec![
        "router".into(),
        format!("{} stalls", rstats.stalls),
        format!("served(max-inflight) per replica: {}", served.join(" ")),
    ]);
    t.row(vec![
        "durability probe".into(),
        format!("{} wal / {} fsync", dstats.wal_appends, dstats.fsyncs),
        format!("{} batches coalesced", dstats.batches_coalesced),
    ]);
    println!(
        "serve [{}] scenario={scenario}: n={n} d={d} level={level} qps={qps} \
         producers={producers} replicas={replicas} maintenance-threads={mtn}",
        curve.name(),
    );
    print!("{}", t.render());
    println!(
        "p99 under churn {} vs quiescent p99 {} ({:.1}x), p999 {}",
        fmt_ns(churn_all.p99()),
        fmt_ns(quiet.p99()),
        churn_all.p99() as f64 / quiet.p99().max(1) as f64,
        fmt_ns(churn_all.p999()),
    );

    // ---- parity: drained pipeline vs a fresh SfcIndex ------------------
    if live_rows.rows == 0 {
        println!("drained; live set empty, parity OK (nothing to verify)");
        return;
    }
    let index = SfcIndex::build_with(&live_rows, level, curve);
    let nv = queries.min(100);
    for _ in 0..nv {
        let c = rng.below_usize(live_rows.rows);
        let (lo, hi) = random_window(live_rows.row(c));
        let mut got = router.query_window(&lo, &hi);
        let mut want: Vec<u32> =
            index.query_window(&lo, &hi).iter().map(|&i| live_ids[i as usize]).collect();
        got.sort_unstable();
        want.sort_unstable();
        assert_eq!(got, want, "router must match a fresh SfcIndex after quiescence");
    }
    println!(
        "drained {} ops ({} rows), {} live rows, parity OK ({nv} windows verified)",
        stats.acked_ops, stats.applied_rows, live_ids.len(),
    );
}
