//! Non-recursive, constant-overhead Hilbert generation (§5, Fig 5).
//!
//! All the information on the recursion stack of the §4 grammar can be
//! recovered from the order value itself: the level of the production rule
//! responsible for the move from `h` to `h+1` is determined by the number
//! of trailing zeros of `h+1`, and a single 2-bit direction register `c`
//! carries the orientation across iterations.
//!
//! Per iteration this costs a `trailing_zeros` (one `TZCNT` instruction — the
//! paper's `_tzcnt_u64`), two shifts, two XORs and two adds: **O(1) time,
//! O(1) space**, in contrast to per-iteration `ℋ⁻¹(h)` (`O(log h)`) and to
//! the recursive grammar (`O(log n)` stack).
//!
//! Direction encoding (paper §5):
//!
//! ```text
//! c = 0 ⇔ look right: j += 1        c = 2 ⇔ look left: j −= 1
//! c = 1 ⇔ look down:  i += 1        c = 3 ⇔ look up:   i −= 1
//! ```
//!
//! The exact flip constants (`c ^= 3·(odd(ℓ−1) ⊕ [a=3])` before the move,
//! `c ^= odd(ℓ−1) ⊕ [a=1]` after, starting from `c = 0`) were fitted and
//! verified exhaustively against the Mealy automaton for all `L ≤ 6`
//! (see the module tests; the paper's Figure 5 prints the same structure
//! with its own sign conventions for the modulo).

use super::hilbert::Hilbert;

/// Coordinate deltas per direction `c` (branch-free via table lookup; the
/// paper uses a sign-preserving modulo for the same purpose).
const DJ: [i32; 4] = [1, 0, -1, 0];
const DI: [i32; 4] = [0, 1, 0, -1];

/// Constant-overhead iterator over the `n×n` Hilbert traversal
/// (`n` a power of two), yielding `(i, j)` pairs in Hilbert order.
///
/// Supports starting at an arbitrary order value (`O(log n)` once) via
/// [`HilbertIter::range`], which is what lets the coordinator hand disjoint
/// *contiguous curve segments* to parallel workers.
#[derive(Clone, Debug)]
pub struct HilbertIter {
    i: u32,
    j: u32,
    h: u64,
    end: u64,
    c: u32,
    level: u32,
}

impl HilbertIter {
    /// Iterate the full `n×n` grid, `n` a power of two (`n ≥ 1`).
    pub fn new(n: u32) -> Self {
        assert!(n.is_power_of_two(), "grid side {n} must be a power of two");
        let level = n.trailing_zeros();
        Self::with_level(level)
    }

    /// Iterate the full grid of side `2^level`.
    pub fn with_level(level: u32) -> Self {
        assert!(level <= 16, "level {level} exceeds supported 16");
        let n = 1u64 << level;
        HilbertIter {
            i: 0,
            j: 0,
            h: 0,
            end: n * n,
            c: 0,
            level,
        }
    }

    /// Iterate the curve segment `[h_start, h_end)` of the `2^level` grid.
    ///
    /// Start-up costs one `ℋ⁻¹` evaluation (`O(level)`); iteration is then
    /// constant-overhead as usual.
    pub fn range(level: u32, h_start: u64, h_end: u64) -> Self {
        assert!(level <= 16, "level {level} exceeds supported 16");
        let n = 1u64 << level;
        let total = n * n;
        assert!(
            h_start <= h_end && h_end <= total,
            "invalid range [{h_start}, {h_end}) for n={n}"
        );
        if h_start == 0 {
            let mut it = Self::with_level(level);
            it.end = h_end;
            return it;
        }
        let (i, j) = Hilbert::coords_at_level(h_start, level);
        // Reconstruct the carried direction register: the move direction
        // h_start → h_start+1 equals c_post(h_start) ⊕ pre(h_start+1), so
        // c_post = dir ⊕ pre. For the last cell there is no next move and
        // the register is never read.
        let c = if h_start + 1 < total {
            let (i2, j2) = Hilbert::coords_at_level(h_start + 1, level);
            let dir = match (i2 as i64 - i as i64, j2 as i64 - j as i64) {
                (0, 1) => 0u32,
                (1, 0) => 1,
                (0, -1) => 2,
                (-1, 0) => 3,
                other => unreachable!("non-unit Hilbert step {other:?}"),
            };
            let (pre, _post) = flips(h_start + 1);
            dir ^ pre
        } else {
            0
        };
        HilbertIter {
            i,
            j,
            h: h_start,
            end: h_end,
            c,
            level,
        }
    }

    /// The current order value (the `h` of the *next* yielded pair).
    #[inline]
    pub fn order_value(&self) -> u64 {
        self.h
    }

    /// Grid level (side = `2^level`).
    #[inline]
    pub fn level(&self) -> u32 {
        self.level
    }
}

/// The two flip masks applied around the move to cell `h` (paper Fig 5
/// lines 6–8 and 11): `pre` is XORed into `c` before the move, `post`
/// after.
#[inline(always)]
fn flips(h: u64) -> (u32, u32) {
    debug_assert!(h > 0);
    let l_minus_1 = h.trailing_zeros() >> 1; // ℓ − 1
    let a = ((h >> (2 * l_minus_1)) & 3) as u32;
    let odd = l_minus_1 & 1;
    let pre = 3 * (odd ^ (a == 3) as u32);
    let post = odd ^ (a == 1) as u32;
    (pre, post)
}

impl Iterator for HilbertIter {
    type Item = (u32, u32);

    #[inline(always)]
    fn next(&mut self) -> Option<(u32, u32)> {
        if self.h >= self.end {
            return None;
        }
        let out = (self.i, self.j);
        self.h += 1;
        if self.h < self.end {
            // Figure 5 inner loop: constant number of ops, branch-free
            // moves via delta tables.
            let (pre, post) = flips(self.h);
            self.c ^= pre;
            self.j = self.j.wrapping_add(DJ[self.c as usize] as u32);
            self.i = self.i.wrapping_add(DI[self.c as usize] as u32);
            self.c ^= post;
        }
        Some(out)
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        let rem = (self.end - self.h) as usize;
        (rem, Some(rem))
    }
}

impl ExactSizeIterator for HilbertIter {}

/// Run `body(i, j)` over the full `n×n` Hilbert traversal — the paper's
/// "preprocessor macro" shape, usable like an ordinary loop statement.
#[inline]
pub fn hilbert_loop_nonrec(n: u32, mut body: impl FnMut(u32, u32)) {
    for (i, j) in HilbertIter::new(n) {
        body(i, j);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::curves::lindenmayer::hilbert_path;
    use crate::util::check::forall;

    #[test]
    fn matches_recursive_grammar() {
        for level in 0..=6u32 {
            let rec = hilbert_path(level);
            let nonrec: Vec<_> = HilbertIter::with_level(level).collect();
            assert_eq!(rec, nonrec, "L={level}");
        }
    }

    #[test]
    fn matches_mealy() {
        for level in [1u32, 3, 5] {
            let n = 1u64 << level;
            for (got, h) in HilbertIter::with_level(level).zip(0..n * n) {
                assert_eq!(got, Hilbert::coords_at_level(h, level));
            }
        }
    }

    #[test]
    fn range_equals_skip_take() {
        let level = 4u32;
        let total = 1u64 << (2 * level);
        for (s, e) in [(0u64, 0u64), (0, 10), (7, 96), (100, 256), (255, 256), (37, 37)] {
            let full: Vec<_> = HilbertIter::with_level(level)
                .skip(s as usize)
                .take((e - s) as usize)
                .collect();
            let ranged: Vec<_> = HilbertIter::range(level, s, e).collect();
            assert_eq!(full, ranged, "[{s},{e}) of {total}");
        }
    }

    #[test]
    fn range_property() {
        forall::<(u32, u32)>("hilbert-range-resume", |&(a, b)| {
            let level = 5u32;
            let total = 1u64 << (2 * level);
            let s = (a as u64) % total;
            let e = s + ((b as u64) % (total - s + 1).min(64));
            let full: Vec<_> = HilbertIter::with_level(level)
                .skip(s as usize)
                .take((e - s) as usize)
                .collect();
            let ranged: Vec<_> = HilbertIter::range(level, s, e.min(total)).collect();
            full == ranged
        });
    }

    #[test]
    fn order_value_tracks_position() {
        let mut it = HilbertIter::new(8);
        assert_eq!(it.order_value(), 0);
        it.next();
        it.next();
        assert_eq!(it.order_value(), 2);
    }

    #[test]
    fn exact_size() {
        let mut it = HilbertIter::new(4);
        assert_eq!(it.len(), 16);
        it.next();
        assert_eq!(it.len(), 15);
    }

    #[test]
    fn single_cell_grid() {
        let v: Vec<_> = HilbertIter::new(1).collect();
        assert_eq!(v, vec![(0, 0)]);
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn non_power_of_two_rejected() {
        HilbertIter::new(6);
    }

    #[test]
    #[should_panic(expected = "invalid range")]
    fn bad_range_rejected() {
        HilbertIter::range(2, 10, 17);
    }
}
