//! d-dimensional layer property tests (ISSUE 2): round-trips for every
//! `CurveKind` at d ∈ {2, 3, 4}, bit-for-bit agreement of the Nd Hilbert
//! with the 2-D Mealy automaton, unit-step locality in d dimensions,
//! batched/scalar agreement for the Nd conversion paths, and the blanket
//! 2-D adapter.

use sfc_mine::coordinator::Coordinator;
use sfc_mine::curves::engine::{collect_nd, for_each_nd, CurveMapper, CurveMapperNd, DomainNd};
use sfc_mine::curves::hilbert::Hilbert;
use sfc_mine::curves::metrics::step_stats_nd;
use sfc_mine::curves::ndim::HilbertNd;
use sfc_mine::curves::CurveKind;
use sfc_mine::util::check::forall_seeded;
use sfc_mine::util::rng::Rng;

/// A level that keeps every kind's cube small enough for exhaustive
/// sweeps at dimension `d` (Peano's side is `3^level`).
fn sweep_level(kind: CurveKind, dims: usize) -> u32 {
    match (kind, dims) {
        (CurveKind::Peano, 2) => 2, // 81 cells
        (CurveKind::Peano, _) => 1, // 27 / 81 cells
        (_, 2) => 4,                // 256 cells
        (_, 3) => 3,                // 512 cells
        _ => 2,                     // 65536 cells at d=4
    }
}

#[test]
fn prop_roundtrip_all_kinds_d234() {
    for kind in CurveKind::ALL {
        for dims in [2usize, 3, 4] {
            let level = sweep_level(kind, dims);
            let mapper = kind.nd_mapper(dims, level);
            let span = mapper.order_span_nd().expect("finite cube");
            let mut p = vec![0u32; dims];
            let mut seen = std::collections::HashSet::new();
            for c in 0..span {
                mapper.coords_nd(c, &mut p);
                assert!(
                    mapper.domain_nd().contains(&p),
                    "{} d={dims} c={c}: point {:?} outside cube",
                    kind.name(),
                    p
                );
                assert_eq!(
                    mapper.order_nd(&p),
                    c,
                    "{} d={dims}: coords_nd(order_nd) != id at c={c}",
                    kind.name()
                );
                assert!(seen.insert(p.clone()), "{} d={dims}: duplicate {:?}", kind.name(), p);
            }
            assert_eq!(seen.len() as u64, span, "{} d={dims}: not a bijection", kind.name());
        }
    }
}

#[test]
fn prop_roundtrip_random_points_at_deep_levels() {
    // Random probes at levels too deep for exhaustive sweeps.
    for (dims, level) in [(2usize, 16u32), (3, 10), (4, 8), (5, 6), (6, 6)] {
        for kind in [CurveKind::ZOrder, CurveKind::Gray, CurveKind::Hilbert] {
            let mapper = kind.nd_mapper(dims, level);
            let side = 1u64 << level;
            let name = format!("nd-roundtrip-{}-d{dims}", kind.name());
            forall_seeded::<(u32, u32)>(&name, 0xD1A5, 64, |&(a, b)| {
                let mut rng = Rng::new(((a as u64) << 32) ^ b as u64 ^ 0x9E37);
                let p: Vec<u32> = (0..dims).map(|_| rng.below(side) as u32).collect();
                let c = mapper.order_nd(&p);
                let mut q = vec![0u32; dims];
                mapper.coords_nd(c, &mut q);
                c < mapper.order_span_nd().unwrap() && q == p
            });
        }
    }
}

#[test]
fn nd_hilbert_d2_is_bitforbit_the_mealy_automaton() {
    // Exhaustive at small levels (both parities)…
    for level in 1..=6u32 {
        let m = HilbertNd::new(2, level);
        let side = 1u32 << level;
        for i in 0..side {
            for j in 0..side {
                let want = Hilbert::order_at_level(i, j, level);
                assert_eq!(m.order_nd(&[i, j]), want, "L={level} ({i},{j})");
                let mut p = [0u32; 2];
                m.coords_nd(want, &mut p);
                assert_eq!(p, [i, j], "L={level} h={want}");
            }
        }
    }
    // …and random probes at deep levels.
    for level in [9u32, 14, 20, 31] {
        let m = HilbertNd::new(2, level);
        let side = 1u64 << level;
        forall_seeded::<(u32, u32)>(&format!("nd-hilbert-mealy-L{level}"), 7, 64, |&(a, b)| {
            let mut rng = Rng::new(((a as u64) << 32) ^ b as u64);
            let (i, j) = (rng.below(side) as u32, rng.below(side) as u32);
            m.order_nd(&[i, j]) == Hilbert::order_at_level(i, j, level)
        });
    }
}

#[test]
fn nd_hilbert_unit_steps_d234() {
    for dims in [2usize, 3, 4] {
        let level = if dims == 4 { 2 } else { 3 };
        let m = HilbertNd::new(dims, level);
        let path = collect_nd(&m);
        let s = step_stats_nd(&path, dims);
        assert_eq!(s.avg, 1.0, "d={dims}: Hilbert must have unit average step");
        assert_eq!(s.max, 1, "d={dims}: Hilbert must have unit max step");
        assert_eq!(s.steps, (1u64 << (dims as u32 * level)) - 1);
    }
}

#[test]
fn prop_nd_batched_conversions_match_scalar() {
    for kind in CurveKind::ALL {
        for dims in [2usize, 3] {
            let level = sweep_level(kind, dims);
            let mapper = kind.nd_mapper(dims, level);
            let span = mapper.order_span_nd().unwrap();
            let name = format!("nd-batch-{}-d{dims}", kind.name());
            forall_seeded::<(u32, u32)>(&name, 23, 32, |&(a, b)| {
                let mut rng = Rng::new(((a as u64) << 32) ^ b as u64 ^ 0xBA7C);
                // Mix consecutive runs (the resume fast path) with jumps.
                let mut orders: Vec<u64> = Vec::new();
                while orders.len() < 150 {
                    let start = rng.below(span);
                    let len = 1 + rng.below(40);
                    for c in start..(start + len).min(span) {
                        orders.push(c);
                    }
                }
                let mut batched = Vec::new();
                mapper.coords_batch_nd(&orders, &mut batched);
                let mut scalar = Vec::new();
                let mut p = vec![0u32; dims];
                for &c in &orders {
                    mapper.coords_nd(c, &mut p);
                    scalar.extend_from_slice(&p);
                }
                if batched != scalar {
                    return false;
                }
                // Forward batch over the decoded points.
                let mut fwd = Vec::new();
                mapper.order_batch_nd(&scalar, &mut fwd);
                fwd == orders
            });
        }
    }
}

#[test]
fn blanket_adapter_makes_2d_mappers_nd() {
    // A plane mapper is a CurveMapperNd with dims() == 2 whose Nd methods
    // agree with the 2-D ones.
    let sq = sfc_mine::curves::engine::HilbertSquare::new(5);
    assert_eq!(CurveMapperNd::dims(&sq), 2);
    assert_eq!(sq.name_nd(), CurveMapper::name(&sq));
    assert_eq!(
        sq.domain_nd(),
        DomainNd::HyperRect { shape: vec![32, 32] }
    );
    assert_eq!(sq.order_span_nd(), CurveMapper::order_span(&sq));
    for (i, j) in [(0u32, 0u32), (3, 7), (31, 31), (16, 5)] {
        let c = CurveMapper::order(&sq, i, j);
        assert_eq!(sq.order_nd(&[i, j]), c);
        let mut p = [0u32; 2];
        sq.coords_nd(c, &mut p);
        assert_eq!(p, [i, j]);
    }
    // segments_nd mirrors segments.
    let via_2d: Vec<(u32, u32)> = CurveMapper::segments(&sq, 100..160).collect();
    let mut via_nd: Vec<(u32, u32)> = Vec::new();
    sq.segments_nd(100..160).for_each(|p| via_nd.push((p[0], p[1])));
    assert_eq!(via_2d, via_nd);
    // Batched paths route through the 2-D batched conversions.
    let orders: Vec<u64> = (0..256u64).chain([40, 9, 1000]).collect();
    let mut flat = Vec::new();
    sq.coords_batch_nd(&orders, &mut flat);
    let mut pairs = Vec::new();
    CurveMapper::coords_batch(&sq, &orders, &mut pairs);
    let flat_want: Vec<u32> = pairs.iter().flat_map(|&(i, j)| [i, j]).collect();
    assert_eq!(flat, flat_want);
}

#[test]
fn par_fold_nd_matches_serial_for_native_and_adapted_mappers() {
    let coord = Coordinator::new(4);
    // Native 3-dim Hilbert cube.
    let cube = HilbertNd::new(3, 3);
    let (par_sum, _) = coord.par_fold_nd(
        &cube,
        || 0u64,
        |acc, p| *acc += p[0] as u64 * 1_000_003 + p[1] as u64 * 1009 + p[2] as u64,
        |a, b| a + b,
    );
    let mut serial = 0u64;
    for_each_nd(&cube, |p| {
        serial += p[0] as u64 * 1_000_003 + p[1] as u64 * 1009 + p[2] as u64;
    });
    assert_eq!(par_sum, serial);
    // Blanket-adapted rectangle mapper (FUR overlay under the hood).
    // par_fold_nd takes `&dyn CurveMapperNd`, so the adapter kicks in at
    // the coercion from the concrete (Sized) 2-D mapper.
    let rect = sfc_mine::curves::engine::RectMapper::fur(9, 21);
    let (nd_sum, _) = coord.par_fold_nd(
        &rect,
        || 0u64,
        |acc, p| *acc += p[0] as u64 * 1009 + p[1] as u64,
        |a, b| a + b,
    );
    let (sum_2d, _) = coord.par_fold(
        &rect,
        || 0u64,
        |acc, i, j| *acc += i as u64 * 1009 + j as u64,
        |a, b| a + b,
    );
    assert_eq!(nd_sum, sum_2d);
}

#[test]
fn nd_mapper_rejects_domains_that_overflow_u64() {
    assert!(std::panic::catch_unwind(|| CurveKind::Hilbert.nd_mapper(8, 8)).is_err());
    assert!(std::panic::catch_unwind(|| CurveKind::Peano.nd_mapper(5, 8)).is_err());
}
