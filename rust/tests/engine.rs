//! Engine-layer property tests (ISSUE 1): batched conversion agrees
//! element-wise with the scalar paths for every `CurveKind`, and the
//! curve-generic `Coordinator::par_fold` visits every cell of arbitrary
//! `n×m` rectangles exactly once, matching the serial fold.

use sfc_mine::coordinator::Coordinator;
use sfc_mine::curves::engine::{for_each, CurveMapper, Domain, FgfMapper, HilbertSquare};
use sfc_mine::curves::fgf::UpperTriangle;
use sfc_mine::curves::CurveKind;
use sfc_mine::util::check::forall_seeded;
use sfc_mine::util::rng::Rng;

/// Keep generated inputs inside every curve's comfortable domain (Peano's
/// digit tables cap at 3^20; stay well below).
fn coord_limit(kind: CurveKind) -> u64 {
    match kind {
        CurveKind::Peano => 3u64.pow(15),
        _ => 1u64 << 31,
    }
}

fn order_limit(kind: CurveKind) -> u64 {
    match kind {
        CurveKind::Peano => 9u64.pow(15),
        // ≤ 4^15 keeps Hilbert's consecutive-run fast path (level ≤ 16)
        // active, which is the branch worth hammering.
        _ => 1u64 << 30,
    }
}

#[test]
fn prop_order_batch_matches_scalar_for_all_curves() {
    for kind in CurveKind::ALL {
        let mapper = kind.mapper();
        let name = format!("order-batch-{}", kind.name());
        forall_seeded::<(u32, u32)>(&name, 17, 48, |&(a, b)| {
            let mut rng = Rng::new(((a as u64) << 32) ^ b as u64 ^ 0x5EED);
            let limit = coord_limit(kind);
            // 2.5 BATCHes plus a ragged tail, mixing tiny and large pairs.
            let pairs: Vec<(u32, u32)> = (0..165)
                .map(|t| {
                    if t % 3 == 0 {
                        (rng.below(16) as u32, rng.below(16) as u32)
                    } else {
                        (rng.below(limit) as u32, rng.below(limit) as u32)
                    }
                })
                .collect();
            let mut batched = Vec::new();
            mapper.order_batch(&pairs, &mut batched);
            let scalar: Vec<u64> = pairs.iter().map(|&(i, j)| mapper.order(i, j)).collect();
            batched == scalar
        });
    }
}

#[test]
fn prop_coords_batch_matches_scalar_for_all_curves() {
    for kind in CurveKind::ALL {
        let mapper = kind.mapper();
        let name = format!("coords-batch-{}", kind.name());
        forall_seeded::<(u32, u32)>(&name, 23, 48, |&(a, b)| {
            let mut rng = Rng::new(((a as u64) << 32) ^ b as u64 ^ 0xFACE);
            let limit = order_limit(kind);
            // Random scatter plus a consecutive run (exercises the
            // amortised stepping path) plus duplicates.
            let mut orders: Vec<u64> = (0..90).map(|_| rng.below(limit)).collect();
            let base = rng.below(limit - 200);
            orders.extend(base..base + 150);
            orders.push(base);
            orders.push(base);
            let mut batched = Vec::new();
            mapper.coords_batch(&orders, &mut batched);
            let scalar: Vec<(u32, u32)> = orders.iter().map(|&c| mapper.coords(c)).collect();
            batched == scalar
        });
    }
}

#[test]
fn prop_batched_roundtrip_through_both_directions() {
    for kind in CurveKind::ALL {
        let mapper = kind.mapper();
        let name = format!("batch-roundtrip-{}", kind.name());
        forall_seeded::<(u32, u32)>(&name, 31, 32, |&(a, b)| {
            let mut rng = Rng::new(((a as u64) << 17) ^ b as u64);
            let limit = coord_limit(kind);
            let pairs: Vec<(u32, u32)> = (0..100)
                .map(|_| (rng.below(limit) as u32, rng.below(limit) as u32))
                .collect();
            let mut orders = Vec::new();
            mapper.order_batch(&pairs, &mut orders);
            let mut back = Vec::new();
            mapper.coords_batch(&orders, &mut back);
            back == pairs
        });
    }
}

#[test]
fn par_fold_visits_every_rect_cell_exactly_once_all_curves() {
    let mut coord = Coordinator::new(3);
    coord.chunk = 37;
    for kind in CurveKind::ALL {
        for (n, m) in [(13u32, 29u32), (32, 32), (27, 9), (1, 17), (24, 24)] {
            let mapper = kind.rect_mapper(n, m);
            assert_eq!(mapper.domain(), Domain::Rect { rows: n, cols: m });
            let (seen, metrics) = coord.par_fold(
                mapper.as_ref(),
                || vec![0u32; (n * m) as usize],
                |acc, i, j| acc[(i * m + j) as usize] += 1,
                |mut a, b| {
                    for (x, y) in a.iter_mut().zip(&b) {
                        *x += *y;
                    }
                    a
                },
            );
            assert!(
                seen.iter().all(|&c| c == 1),
                "{} {n}x{m}: cell visited != once",
                kind.name()
            );
            let items: u64 = metrics.iter().map(|w| w.items).sum();
            assert_eq!(items, n as u64 * m as u64, "{} {n}x{m}", kind.name());
        }
    }
}

#[test]
fn par_fold_matches_serial_fold_all_curves() {
    let mut coord = Coordinator::new(4);
    coord.chunk = 53;
    for kind in CurveKind::ALL {
        let mapper = kind.rect_mapper(21, 34);
        let (par_sum, _) = coord.par_fold(
            mapper.as_ref(),
            || 0u64,
            |s, i, j| *s += (i as u64) * 1_000_003 + j as u64,
            |a, b| a + b,
        );
        let mut serial = 0u64;
        for_each(mapper.as_ref(), |i, j| serial += (i as u64) * 1_000_003 + j as u64);
        assert_eq!(par_sum, serial, "{}", kind.name());
    }
}

#[test]
fn par_fold_segments_concatenate_to_the_full_path() {
    // Serial check of the scheduling invariant: chunked segments glued in
    // order equal the full traversal, for every curve and a ragged chunk
    // size.
    for kind in CurveKind::ALL {
        let mapper = kind.rect_mapper(11, 19);
        let span = mapper.domain().order_span().unwrap();
        let full: Vec<(u32, u32)> = mapper.segments(0..span).collect();
        let mut glued = Vec::new();
        let mut start = 0u64;
        while start < span {
            let end = (start + 23).min(span);
            glued.extend(mapper.segments(start..end));
            start = end;
        }
        assert_eq!(glued, full, "{}", kind.name());
    }
}

#[test]
fn par_fold_over_fgf_region_matches_serial_traverse() {
    let mut coord = Coordinator::new(3);
    coord.chunk = 100;
    let level = 5u32;
    let mapper = FgfMapper::new(level, UpperTriangle);
    let (par_sum, _) = coord.par_fold(
        &mapper,
        || 0u64,
        |s, i, j| *s += (i as u64) << 16 | j as u64,
        |a, b| a + b,
    );
    let mut serial = 0u64;
    mapper.traverse(|i, j, _h| serial += (i as u64) << 16 | j as u64);
    assert_eq!(par_sum, serial);
    let n = 1u64 << level;
    assert_eq!(mapper.domain().cell_count(), Some(n * (n - 1) / 2));
}

#[test]
fn hilbert_square_par_fold_equals_legacy_hilbert_fold() {
    let coord = Coordinator::new(2);
    let level = 4u32;
    let sq = HilbertSquare::new(level);
    let (a, _) = coord.par_fold(
        &sq,
        || 0u64,
        |s, i, j| *s += (i as u64) * 77 + j as u64,
        |x, y| x + y,
    );
    let (b, _) = coord.par_hilbert_fold(
        level,
        || 0u64,
        |s, i, j| *s += (i as u64) * 77 + j as u64,
        |x, y| x + y,
    );
    assert_eq!(a, b);
}

#[test]
fn rect_mapper_order_and_coords_are_inverse() {
    for kind in CurveKind::ALL {
        let mapper = kind.rect_mapper(14, 6);
        let span = mapper.domain().order_span().unwrap();
        for c in 0..span {
            let (i, j) = mapper.coords(c);
            assert_eq!(mapper.order(i, j), c, "{} c={c}", kind.name());
        }
    }
}
