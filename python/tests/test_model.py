"""L2 model correctness: padded-odd shapes, Lloyd-step semantics."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def rand(key, *shape):
    return jax.random.uniform(jax.random.PRNGKey(key), shape, jnp.float32, -3.0, 3.0)


class TestPairwiseDists:
    @settings(max_examples=15, deadline=None)
    @given(n=st.integers(1, 50), k=st.integers(1, 20), d=st.integers(1, 10), seed=st.integers(0, 999))
    def test_odd_shapes_match_ref(self, n, k, d, seed):
        x = rand(seed, n, d)
        c = rand(seed + 1, k, d)
        got = model.pairwise_dists(x, c)
        np.testing.assert_allclose(got, ref.pairwise_sq_dists(x, c), rtol=1e-4, atol=1e-4)


class TestMatmulModel:
    @settings(max_examples=15, deadline=None)
    @given(n=st.integers(1, 40), k=st.integers(1, 40), m=st.integers(1, 40), seed=st.integers(0, 999))
    def test_odd_shapes_match_ref(self, n, k, m, seed):
        a = rand(seed, n, k)
        b = rand(seed + 1, k, m)
        got = model.matmul(a, b)
        np.testing.assert_allclose(got, ref.matmul(a, b), rtol=1e-3, atol=1e-3)


class TestKmeansStep:
    def _check(self, n, d, k, seed):
        pts = rand(seed, n, d)
        cents = rand(seed + 1, k, d)
        labels, counts, sums, inertia = model.kmeans_step(pts, cents)
        rl, rc, rs, ri = ref.kmeans_step(pts, cents)
        np.testing.assert_array_equal(labels, rl)
        np.testing.assert_allclose(counts, rc)
        np.testing.assert_allclose(sums, rs, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(inertia, ri, rtol=1e-4)
        # Invariants.
        assert labels.shape == (n,)
        assert counts.shape == (k,)
        assert sums.shape == (k, d)
        assert float(jnp.sum(counts)) == n
        assert float(inertia) >= 0.0

    def test_tile_aligned(self):
        self._check(128, 16, 128, 3)

    def test_odd_shapes(self):
        self._check(100, 7, 13, 5)
        self._check(33, 3, 5, 7)
        self._check(5, 2, 3, 11)

    @settings(max_examples=10, deadline=None)
    @given(n=st.integers(2, 60), d=st.integers(1, 8), k=st.integers(1, 10), seed=st.integers(0, 999))
    def test_hypothesis_sweep(self, n, d, k, seed):
        self._check(n, d, k, seed)

    def test_centroid_update_reduces_inertia(self):
        # Lloyd's guarantee, through the model path.
        pts = rand(42, 200, 4)
        cents = rand(43, 8, 4)
        _, counts, sums, inertia0 = model.kmeans_step(pts, cents)
        counts = jnp.maximum(counts, 1.0)
        new_cents = sums / counts[:, None]
        _, _, _, inertia1 = model.kmeans_step(pts, new_cents)
        assert float(inertia1) <= float(inertia0) + 1e-3
