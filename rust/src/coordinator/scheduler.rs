//! Curve-segment scheduling: hand out contiguous Hilbert-order ranges.
//!
//! Contiguity is the point — a contiguous order-value range is a spatially
//! compact blob of the grid (the Hilbert curve's defining property), so a
//! worker that processes one chunk end-to-end enjoys the same locality the
//! serial loop would.

use std::sync::atomic::{AtomicU64, Ordering};

/// Dynamic chunk queue over the order-value range `[0, total)`.
///
/// Lock-free: a single atomic cursor; each `next_chunk` claims the next
/// `chunk`-sized contiguous segment.
#[derive(Debug)]
pub struct ChunkQueue {
    cursor: AtomicU64,
    total: u64,
    chunk: u64,
}

impl ChunkQueue {
    /// Queue over `[0, total)` with the given chunk size (≥ 1).
    pub fn new(total: u64, chunk: u64) -> Self {
        assert!(chunk >= 1, "chunk size must be ≥ 1");
        ChunkQueue { cursor: AtomicU64::new(0), total, chunk }
    }

    /// Claim the next chunk; `None` once the range is exhausted.
    ///
    /// Compare-exchange rather than an unconditional `fetch_add`: the
    /// cursor is clamped at `total`, so a long-lived queue polled after
    /// exhaustion can never advance the atomic further (an unconditional
    /// add would keep growing it and could in principle wrap `u64` and
    /// restart the range), and [`ChunkQueue::remaining`] stays exact.
    #[inline]
    pub fn next_chunk(&self) -> Option<(u64, u64)> {
        let mut start = self.cursor.load(Ordering::Relaxed);
        loop {
            if start >= self.total {
                return None;
            }
            let end = (start + self.chunk).min(self.total);
            match self
                .cursor
                .compare_exchange_weak(start, end, Ordering::Relaxed, Ordering::Relaxed)
            {
                Ok(_) => return Some((start, end)),
                Err(seen) => start = seen,
            }
        }
    }

    /// Remaining order values (exact: the cursor never exceeds `total`).
    pub fn remaining(&self) -> u64 {
        self.total.saturating_sub(self.cursor.load(Ordering::Relaxed))
    }
}

/// Static partition of `[0, total)` into `parts` near-equal contiguous
/// ranges (the zero-coordination alternative to [`ChunkQueue`]).
pub fn static_ranges(total: u64, parts: usize) -> Vec<(u64, u64)> {
    assert!(parts >= 1);
    let parts = parts as u64;
    let base = total / parts;
    let rem = total % parts;
    let mut out = Vec::with_capacity(parts as usize);
    let mut start = 0u64;
    for p in 0..parts {
        let len = base + u64::from(p < rem);
        out.push((start, start + len));
        start += len;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_partition_range() {
        let q = ChunkQueue::new(100, 7);
        let mut seen = vec![false; 100];
        while let Some((s, e)) = q.next_chunk() {
            for x in s..e {
                assert!(!seen[x as usize], "duplicate at {x}");
                seen[x as usize] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn concurrent_claims_are_disjoint() {
        let q = ChunkQueue::new(10_000, 13);
        let mut claimed: Vec<(u64, u64)> = Vec::new();
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..8)
                .map(|_| {
                    scope.spawn(|| {
                        let mut mine = Vec::new();
                        while let Some(c) = q.next_chunk() {
                            mine.push(c);
                        }
                        mine
                    })
                })
                .collect();
            for h in handles {
                claimed.extend(h.join().unwrap());
            }
        });
        claimed.sort_unstable();
        let total: u64 = claimed.iter().map(|&(s, e)| e - s).sum();
        assert_eq!(total, 10_000);
        for w in claimed.windows(2) {
            assert_eq!(w[0].1, w[1].0, "gap or overlap between {:?} and {:?}", w[0], w[1]);
        }
    }

    #[test]
    fn remaining_decreases() {
        let q = ChunkQueue::new(20, 10);
        assert_eq!(q.remaining(), 20);
        q.next_chunk();
        assert_eq!(q.remaining(), 10);
    }

    #[test]
    fn exhausted_queue_cursor_stays_clamped() {
        // Polling an exhausted queue must not advance the cursor (the old
        // unconditional fetch_add kept growing it, so a long-lived queue
        // could in principle wrap u64 and hand out the range again).
        let q = ChunkQueue::new(25, 10);
        while q.next_chunk().is_some() {}
        for _ in 0..1000 {
            assert_eq!(q.next_chunk(), None);
            assert_eq!(q.remaining(), 0);
        }
        assert_eq!(q.cursor.load(std::sync::atomic::Ordering::Relaxed), 25);
    }

    #[test]
    fn final_chunk_is_clamped_to_total() {
        let q = ChunkQueue::new(25, 10);
        assert_eq!(q.next_chunk(), Some((0, 10)));
        assert_eq!(q.next_chunk(), Some((10, 20)));
        assert_eq!(q.next_chunk(), Some((20, 25)));
        assert_eq!(q.next_chunk(), None);
    }

    #[test]
    fn static_ranges_cover() {
        for (total, parts) in [(100u64, 3usize), (7, 10), (0, 2), (64, 64)] {
            let ranges = static_ranges(total, parts);
            assert_eq!(ranges.len(), parts);
            let sum: u64 = ranges.iter().map(|&(s, e)| e - s).sum();
            assert_eq!(sum, total);
            for w in ranges.windows(2) {
                assert_eq!(w[0].1, w[1].0);
            }
            // Near-equal: lengths differ by at most 1.
            let lens: Vec<u64> = ranges.iter().map(|&(s, e)| e - s).collect();
            let (mn, mx) = (lens.iter().min().unwrap(), lens.iter().max().unwrap());
            assert!(mx - mn <= 1);
        }
    }
}
