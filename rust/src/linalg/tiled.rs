//! Curve-ordered tiled matrix storage (paper §6–§7).
//!
//! A [`TiledMatrix`] splits an `rows × cols` matrix into `tile × tile`
//! blocks and stores the blocks **contiguously in curve order**: block
//! `(bi, bj)` lives at slot `C(bi, bj)` of the flat buffer, where `C` is
//! any engine rect mapper ([`CurveKind::rect_mapper`] — FUR-Hilbert on
//! arbitrary shapes, the Figure-5 square on powers of two, closed-form
//! canonic as the baseline). Two effects compound:
//!
//! 1. **Within a tile**, all `tile²` elements are one contiguous span —
//!    a working set the innermost kernel never leaves.
//! 2. **Across tiles**, blocks that are close on the curve are close in
//!    memory, so a kernel that *traverses* tile tasks in curve order
//!    (see [`crate::apps::matmul::matmul_tiles`]) touches a physically
//!    clustered neighborhood at every cache level simultaneously — the
//!    cache-oblivious layout the paper's §6 recursion argument predicts.
//!
//! Edge tiles (non-multiple sizes) are zero-padded to full `tile × tile`
//! spans; kernels iterate the *actual* extents
//! ([`TiledMatrix::tile_rows_at`] / [`TiledMatrix::tile_cols_at`]).

use crate::apps::Matrix;
use crate::curves::CurveKind;

/// A dense `f32` matrix stored as curve-ordered `tile × tile` blocks.
///
/// See the [module docs](self) for the layout rationale. Conversion to
/// and from the row-major [`Matrix`] is exact ([`TiledMatrix::from_matrix`]
/// / [`TiledMatrix::to_matrix`]).
#[derive(Clone, Debug)]
pub struct TiledMatrix {
    rows: usize,
    cols: usize,
    tile: usize,
    tile_rows: usize,
    tile_cols: usize,
    kind: CurveKind,
    /// Tile-grid row-major `(bi · tile_cols + bj)` → curve slot.
    slots: Vec<u32>,
    /// Curve slot → tile-grid coordinates.
    tiles: Vec<(u32, u32)>,
    /// `tile_rows · tile_cols · tile²` entries; slot `s` owns
    /// `data[s · tile² .. (s+1) · tile²]`, row-major within the tile.
    pub data: Vec<f32>,
}

impl TiledMatrix {
    /// Zero matrix in curve-tiled layout.
    ///
    /// # Panics
    /// Panics on an empty shape, a zero tile size, or a tile grid larger
    /// than `u32` slots.
    pub fn zeros(rows: usize, cols: usize, tile: usize, kind: CurveKind) -> Self {
        assert!(rows > 0 && cols > 0, "empty matrices have no tiling");
        assert!(tile > 0, "tile size must be ≥ 1");
        let tile_rows = rows.div_ceil(tile);
        let tile_cols = cols.div_ceil(tile);
        assert!(
            tile_rows as u64 * tile_cols as u64 <= u32::MAX as u64,
            "tile grid exceeds u32 slots"
        );
        let mapper = kind.rect_mapper(tile_rows as u32, tile_cols as u32);
        let span = mapper.order_span().expect("rect mappers are finite");
        let mut slots = vec![0u32; tile_rows * tile_cols];
        let mut tiles = Vec::with_capacity(span as usize);
        for (slot, (bi, bj)) in mapper.segments(0..span).enumerate() {
            slots[bi as usize * tile_cols + bj as usize] = slot as u32;
            tiles.push((bi, bj));
        }
        debug_assert_eq!(tiles.len(), tile_rows * tile_cols);
        TiledMatrix {
            rows,
            cols,
            tile,
            tile_rows,
            tile_cols,
            kind,
            slots,
            tiles,
            data: vec![0.0; tile_rows * tile_cols * tile * tile],
        }
    }

    /// Convert a row-major [`Matrix`] into curve-tiled layout (exact;
    /// edge tiles zero-padded).
    pub fn from_matrix(m: &Matrix, tile: usize, kind: CurveKind) -> Self {
        let mut out = Self::zeros(m.rows, m.cols, tile, kind);
        for bi in 0..out.tile_rows {
            for bj in 0..out.tile_cols {
                let slot = out.slot(bi, bj);
                let (ri, rj) = (out.tile_rows_at(bi), out.tile_cols_at(bj));
                let base = slot * tile * tile;
                for r in 0..ri {
                    let src = (bi * tile + r) * m.cols + bj * tile;
                    out.data[base + r * tile..base + r * tile + rj]
                        .copy_from_slice(&m.data[src..src + rj]);
                }
            }
        }
        out
    }

    /// Convert back to a row-major [`Matrix`] (exact).
    pub fn to_matrix(&self) -> Matrix {
        let mut m = Matrix::zeros(self.rows, self.cols);
        let tile = self.tile;
        for bi in 0..self.tile_rows {
            for bj in 0..self.tile_cols {
                let base = self.slot(bi, bj) * tile * tile;
                let (ri, rj) = (self.tile_rows_at(bi), self.tile_cols_at(bj));
                for r in 0..ri {
                    let dst = (bi * tile + r) * self.cols + bj * tile;
                    m.data[dst..dst + rj]
                        .copy_from_slice(&self.data[base + r * tile..base + r * tile + rj]);
                }
            }
        }
        m
    }

    /// Matrix rows.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Matrix columns.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Tile side length.
    pub fn tile_size(&self) -> usize {
        self.tile
    }

    /// Elements per tile span (`tile²`, including padding).
    pub fn tile_len(&self) -> usize {
        self.tile * self.tile
    }

    /// Number of tile rows (`⌈rows / tile⌉`).
    pub fn tile_rows(&self) -> usize {
        self.tile_rows
    }

    /// Number of tile columns (`⌈cols / tile⌉`).
    pub fn tile_cols(&self) -> usize {
        self.tile_cols
    }

    /// Total number of tiles.
    pub fn num_tiles(&self) -> usize {
        self.tiles.len()
    }

    /// The curve ordering the tiles are laid out in.
    pub fn kind(&self) -> CurveKind {
        self.kind
    }

    /// Actual row count of tile row `bi` (< `tile` on the bottom edge).
    #[inline]
    pub fn tile_rows_at(&self, bi: usize) -> usize {
        self.tile.min(self.rows - bi * self.tile)
    }

    /// Actual column count of tile column `bj` (< `tile` on the right
    /// edge).
    #[inline]
    pub fn tile_cols_at(&self, bj: usize) -> usize {
        self.tile.min(self.cols - bj * self.tile)
    }

    /// Curve slot of tile `(bi, bj)` — its rank in the storage order.
    #[inline]
    pub fn slot(&self, bi: usize, bj: usize) -> usize {
        self.slots[bi * self.tile_cols + bj] as usize
    }

    /// Tile-grid coordinates of a curve slot (inverse of
    /// [`TiledMatrix::slot`]).
    #[inline]
    pub fn tile_coords(&self, slot: usize) -> (usize, usize) {
        let (bi, bj) = self.tiles[slot];
        (bi as usize, bj as usize)
    }

    /// The `tile²` span of one slot.
    #[inline]
    pub fn tile(&self, slot: usize) -> &[f32] {
        let len = self.tile_len();
        &self.data[slot * len..(slot + 1) * len]
    }

    /// Mutable span of one slot.
    #[inline]
    pub fn tile_mut(&mut self, slot: usize) -> &mut [f32] {
        let len = self.tile_len();
        &mut self.data[slot * len..(slot + 1) * len]
    }

    /// Element accessor (slow path — tests and spot checks; kernels work
    /// on whole tile spans).
    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        debug_assert!(i < self.rows && j < self.cols);
        let (bi, bj) = (i / self.tile, j / self.tile);
        self.tile(self.slot(bi, bj))[(i % self.tile) * self.tile + j % self.tile]
    }

    /// Copy of the tile-placement metadata without the payload — what a
    /// parallel kernel needs alongside a [`TileCells`] view.
    pub(crate) fn meta(&self) -> TileMeta {
        TileMeta {
            rows: self.rows,
            cols: self.cols,
            tile: self.tile,
            tile_cols: self.tile_cols,
            slots: self.slots.clone(),
        }
    }
}

/// Placement metadata of a [`TiledMatrix`] (shape, tile grid, slot
/// table) detached from the payload, so task bodies can resolve slots
/// and extents while a [`TileCells`] view owns the data borrow.
#[derive(Clone, Debug)]
pub(crate) struct TileMeta {
    pub rows: usize,
    pub cols: usize,
    pub tile: usize,
    tile_cols: usize,
    slots: Vec<u32>,
}

impl TileMeta {
    /// Curve slot of tile `(bi, bj)` (see [`TiledMatrix::slot`]).
    #[inline]
    pub fn slot(&self, bi: usize, bj: usize) -> usize {
        self.slots[bi * self.tile_cols + bj] as usize
    }

    /// Actual row count of tile row `bi`.
    #[inline]
    pub fn tile_rows_at(&self, bi: usize) -> usize {
        self.tile.min(self.rows - bi * self.tile)
    }

    /// Actual column count of tile column `bj`.
    #[inline]
    pub fn tile_cols_at(&self, bj: usize) -> usize {
        self.tile.min(self.cols - bj * self.tile)
    }
}

/// Shared mutable view of a [`TiledMatrix`]'s payload for
/// dependency-scheduled tile tasks
/// ([`Coordinator::par_linalg`](crate::coordinator::Coordinator::par_linalg)).
///
/// The scheduler's task graph — not the borrow checker — serializes
/// conflicting tile accesses, so the accessors are `unsafe`:
///
/// # Safety contract
/// While a task holds `tile_mut(s)`, no concurrently-runnable task may
/// call `tile(s)` or `tile_mut(s)` for the same slot. The linalg kernels
/// uphold this structurally: a task writes only its own tile and reads
/// only tiles whose final value was produced by a predecessor in the
/// [`TaskGraph`](crate::coordinator::TaskGraph).
pub(crate) struct TileCells<'a> {
    ptr: *mut f32,
    len: usize,
    tile_len: usize,
    _data: std::marker::PhantomData<&'a mut [f32]>,
}

// SAFETY: the raw pointer is only dereferenced through the unsafe
// accessors, whose disjointness contract (above) makes the shared view
// data-race free.
unsafe impl Send for TileCells<'_> {}
unsafe impl Sync for TileCells<'_> {}

impl<'a> TileCells<'a> {
    /// View over a tiled payload; the borrow of `data` lives as long as
    /// the view, so the owning [`TiledMatrix`] stays frozen meanwhile.
    pub(crate) fn new(data: &'a mut [f32], tile_len: usize) -> Self {
        debug_assert_eq!(data.len() % tile_len, 0);
        TileCells {
            ptr: data.as_mut_ptr(),
            len: data.len(),
            tile_len,
            _data: std::marker::PhantomData,
        }
    }

    /// Exclusive span of one slot.
    ///
    /// # Safety
    /// Caller must guarantee no concurrent access to this slot (see the
    /// type-level contract).
    #[inline]
    #[allow(clippy::mut_from_ref)]
    pub(crate) unsafe fn tile_mut(&self, slot: usize) -> &mut [f32] {
        debug_assert!((slot + 1) * self.tile_len <= self.len);
        std::slice::from_raw_parts_mut(self.ptr.add(slot * self.tile_len), self.tile_len)
    }

    /// Shared span of one slot.
    ///
    /// # Safety
    /// Caller must guarantee no concurrent *write* to this slot (see the
    /// type-level contract).
    #[inline]
    pub(crate) unsafe fn tile(&self, slot: usize) -> &[f32] {
        debug_assert!((slot + 1) * self.tile_len <= self.len);
        std::slice::from_raw_parts(self.ptr.add(slot * self.tile_len), self.tile_len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_is_exact() {
        for (rows, cols, tile) in [(7, 13, 4), (16, 16, 5), (1, 9, 3), (33, 20, 8), (5, 5, 64)] {
            let m = Matrix::random(rows, cols, 3, -1.0, 1.0);
            for kind in CurveKind::ALL {
                let tm = TiledMatrix::from_matrix(&m, tile, kind);
                assert_eq!(tm.to_matrix(), m, "{} {rows}x{cols} t={tile}", kind.name());
            }
        }
    }

    #[test]
    fn slots_are_a_curve_permutation() {
        let tm = TiledMatrix::zeros(40, 24, 8, CurveKind::Hilbert);
        assert_eq!(tm.tile_rows(), 5);
        assert_eq!(tm.tile_cols(), 3);
        assert_eq!(tm.num_tiles(), 15);
        let mut seen = vec![false; 15];
        for bi in 0..5 {
            for bj in 0..3 {
                let s = tm.slot(bi, bj);
                assert!(!seen[s], "slot {s} reused");
                seen[s] = true;
                assert_eq!(tm.tile_coords(s), (bi, bj));
            }
        }
        // Slot order IS the mapper's curve order.
        let mapper = CurveKind::Hilbert.rect_mapper(5, 3);
        for (slot, (bi, bj)) in mapper.segments(0..15).enumerate() {
            assert_eq!(tm.slot(bi as usize, bj as usize), slot);
        }
    }

    #[test]
    fn edge_tiles_are_zero_padded() {
        let m = Matrix::from_fn(5, 5, |_, _| 1.0);
        let tm = TiledMatrix::from_matrix(&m, 4, CurveKind::Hilbert);
        assert_eq!(tm.tile_rows_at(1), 1);
        assert_eq!(tm.tile_cols_at(1), 1);
        let corner = tm.tile(tm.slot(1, 1));
        assert_eq!(corner.iter().filter(|&&x| x != 0.0).count(), 1);
        assert_eq!(corner[0], 1.0);
    }

    #[test]
    fn at_matches_row_major() {
        let m = Matrix::from_fn(9, 7, |i, j| (i * 100 + j) as f32);
        let tm = TiledMatrix::from_matrix(&m, 4, CurveKind::ZOrder);
        for i in 0..9 {
            for j in 0..7 {
                assert_eq!(tm.at(i, j), m.at(i, j));
            }
        }
    }

    #[test]
    fn tile_cells_views_are_disjoint() {
        let mut tm = TiledMatrix::zeros(8, 8, 4, CurveKind::Hilbert);
        let len = tm.tile_len();
        let cells = TileCells::new(&mut tm.data, len);
        // SAFETY: slots 0 and 1 are distinct, single-threaded here.
        unsafe {
            cells.tile_mut(0)[0] = 1.0;
            cells.tile_mut(1)[0] = 2.0;
            assert_eq!(cells.tile(0)[0], 1.0);
            assert_eq!(cells.tile(1)[0], 2.0);
        }
        assert_eq!(tm.data[0], 1.0);
        assert_eq!(tm.data[len], 2.0);
    }

    #[test]
    #[should_panic(expected = "tile size")]
    fn zero_tile_rejected() {
        TiledMatrix::zeros(4, 4, 0, CurveKind::Hilbert);
    }
}
