//! The MIMD coordinator: parallel execution of curve-ordered work
//! (paper §7's "parallel threads on multiple cores").
//!
//! The key design point is *locality-preserving partitioning*: a mapper's
//! order-value range is cut into **contiguous curve segments**, so each
//! worker's accesses stay spatially clustered (per-worker cache locality),
//! while dynamic chunk hand-out keeps the load balanced.
//!
//! The scheduling core is [`Coordinator::par_fold`]: it takes any
//! finite-domain [`CurveMapper`] — a power-of-two Hilbert square, a FUR
//! rectangle, a filtered cover, or an FGF region — so every curve and
//! every `n×m` shape parallelises through one code path.
//! [`Coordinator::par_hilbert_fold`] is the Hilbert-square convenience
//! wrapper, and [`Coordinator::par_fold_nd`] is the same scheduler over
//! **d-dimensional** domains: any finite [`CurveMapperNd`] (a native
//! hypercube curve or a blanket-adapted 2-D mapper) is cut into the same
//! contiguous [`ChunkQueue`] segments, with the worker body receiving
//! `&[u32]` points. For task spaces that are *not* one contiguous order
//! range — the blocked linear-algebra kernels of [`crate::linalg`] —
//! [`Coordinator::par_linalg`] executes a [`TaskGraph`] whose ready queue
//! is ordered by tile curve order, so dependency-constrained work (matmul
//! output tiles, left-looking Cholesky panels, Floyd–Warshall wavefront
//! rounds) keeps the same locality-preserving hand-out.
//!
//! For batched serving work the same chunk queue generalizes to
//! [`Coordinator::par_map`] (dynamic map over an item slice, results in
//! input order): [`Coordinator::par_query`] fans window batches over an
//! [`SfcIndex`], [`Coordinator::par_query_store`] over one consistent
//! [`SfcStore`] snapshot, and the store's planner routes a *single*
//! window's decomposed ranges to per-shard probe tasks through it
//! ([`SfcStore::par_query_window`]).
//!
//! * [`scheduler`] — curve-segment scheduling (static ranges + dynamic
//!   chunk queue).
//! * [`pool`] — a long-lived worker pool (std threads; the vendored crate
//!   set has no tokio, and this hot path is pure compute — see DESIGN.md
//!   §3).
//! * [`batch`] — fixed-size batching for PJRT kernel invocations.
//! * [`metrics`] — per-worker counters.
//!
//! The flagship application is [`par_kmeans_step`]: a parallel Lloyd
//! iteration whose point range is sharded into contiguous segments, with
//! per-worker partial centroid sums merged at the barrier.

pub mod async_model;
pub mod batch;
pub mod metrics;
pub mod pool;
pub mod scheduler;

use crate::apps::kmeans::{Assignment, KMeans};
use crate::apps::Matrix;
use crate::curves::engine::{self, CurveMapper, CurveMapperNd, HilbertSquare};
use crate::curves::CurveKind;
use crate::index::{SfcIndex, SfcStore};
use metrics::WorkerMetrics;
use scheduler::ChunkQueue;

pub use scheduler::TaskGraph;

/// The coordinator: owns a worker count and dispatches Hilbert-ordered
/// work across scoped threads.
#[derive(Clone, Debug)]
pub struct Coordinator {
    threads: usize,
    /// Hilbert chunk size (order values per hand-out).
    pub chunk: u64,
}

impl Coordinator {
    /// Coordinator with `threads` workers (0 = one per available core).
    pub fn new(threads: usize) -> Self {
        let threads = if threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            threads
        };
        Coordinator { threads, chunk: 4096 }
    }

    /// Worker count.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `body` over every cell of a finite-domain [`CurveMapper`] in
    /// parallel: workers pull contiguous curve segments (order-value
    /// chunks) from a dynamic queue; each worker folds into its own state
    /// `S`, and the states are merged at the end.
    ///
    /// Works for any curve over any `n×m` rectangle (via
    /// [`CurveKind::rect_mapper`]) and for FGF region mappers (whose
    /// sparse order values make some chunks cheap no-ops).
    ///
    /// Returns the merged state and per-worker metrics (a worker's `items`
    /// counts order values of its chunks, which for sparse domains can
    /// exceed the cells actually visited).
    ///
    /// # Panics
    /// Panics if the mapper's domain is the unbounded plane.
    pub fn par_fold<S, I, B, M>(
        &self,
        mapper: &dyn CurveMapper,
        init: I,
        body: B,
        mut merge: M,
    ) -> (S, Vec<WorkerMetrics>)
    where
        S: Send,
        I: Fn() -> S + Sync,
        B: Fn(&mut S, u32, u32) + Sync,
        M: FnMut(S, S) -> S,
    {
        let total = mapper
            .order_span()
            .expect("par_fold requires a finite-domain mapper (rect/region)");
        let queue = ChunkQueue::new(total, self.chunk);
        let mut results: Vec<(S, WorkerMetrics)> = Vec::with_capacity(self.threads);
        std::thread::scope(|scope| {
            let mut handles = Vec::with_capacity(self.threads);
            for worker_id in 0..self.threads {
                let queue = &queue;
                let init = &init;
                let body = &body;
                handles.push(scope.spawn(move || {
                    let mut state = init();
                    let mut m = WorkerMetrics::new(worker_id);
                    while let Some((start, end)) = queue.next_chunk() {
                        let t0 = std::time::Instant::now();
                        for (i, j) in mapper.segments(start..end) {
                            body(&mut state, i, j);
                        }
                        m.record_chunk(end - start, t0.elapsed());
                    }
                    (state, m)
                }));
            }
            for h in handles {
                results.push(h.join().expect("worker panicked"));
            }
        });
        let mut metrics = Vec::with_capacity(self.threads);
        let mut merged: Option<S> = None;
        for (state, m) in results {
            metrics.push(m);
            merged = Some(match merged {
                None => state,
                Some(acc) => merge(acc, state),
            });
        }
        (merged.expect("at least one worker"), metrics)
    }

    /// Run `body` over every point of a finite-domain [`CurveMapperNd`]
    /// in parallel — [`Coordinator::par_fold`] for **d-dimensional**
    /// domains, scheduled through the same [`ChunkQueue`] of contiguous
    /// curve segments. The body receives each point as a `&[u32]` slice
    /// of `mapper.dims()` coordinates (lent from a per-worker buffer, so
    /// the traversal does not allocate per cell).
    ///
    /// # Panics
    /// Panics if the mapper's domain is unbounded.
    pub fn par_fold_nd<S, I, B, M>(
        &self,
        mapper: &dyn CurveMapperNd,
        init: I,
        body: B,
        mut merge: M,
    ) -> (S, Vec<WorkerMetrics>)
    where
        S: Send,
        I: Fn() -> S + Sync,
        B: Fn(&mut S, &[u32]) + Sync,
        M: FnMut(S, S) -> S,
    {
        let total = mapper
            .order_span_nd()
            .expect("par_fold_nd requires a finite-domain mapper");
        let queue = ChunkQueue::new(total, self.chunk);
        let mut results: Vec<(S, WorkerMetrics)> = Vec::with_capacity(self.threads);
        std::thread::scope(|scope| {
            let mut handles = Vec::with_capacity(self.threads);
            for worker_id in 0..self.threads {
                let queue = &queue;
                let init = &init;
                let body = &body;
                handles.push(scope.spawn(move || {
                    let mut state = init();
                    let mut m = WorkerMetrics::new(worker_id);
                    while let Some((start, end)) = queue.next_chunk() {
                        let t0 = std::time::Instant::now();
                        let mut seg = mapper.segments_nd(start..end);
                        while let Some(p) = seg.next_point() {
                            body(&mut state, p);
                        }
                        m.record_chunk(end - start, t0.elapsed());
                    }
                    (state, m)
                }));
            }
            for h in handles {
                results.push(h.join().expect("worker panicked"));
            }
        });
        let mut metrics = Vec::with_capacity(self.threads);
        let mut merged: Option<S> = None;
        for (state, m) in results {
            metrics.push(m);
            merged = Some(match merged {
                None => state,
                Some(acc) => merge(acc, state),
            });
        }
        (merged.expect("at least one worker"), metrics)
    }

    /// Execute a [`TaskGraph`] across the worker pool — the
    /// **dependency-aware** companion to [`Coordinator::par_fold`] for
    /// task spaces that are not a single contiguous order range (blocked
    /// linear algebra: per-output-tile matmul accumulation, left-looking
    /// Cholesky panels, Floyd–Warshall wavefront rounds).
    ///
    /// Workers pull the ready task with the **lowest priority value**
    /// (linalg kernels set priorities to tile curve order values, so
    /// execution stays spatially clustered whenever the DAG admits it),
    /// run `body(task)`, then unlock dependents. The graph itself is not
    /// consumed — in-degrees are copied per run, so one graph can drive
    /// many rounds.
    ///
    /// `body` observes every predecessor's writes: the unlock handshake
    /// goes through a mutex, so tasks ordered by an edge are also ordered
    /// by happens-before. Disjoint tasks may run concurrently — sharing
    /// mutable state across *unordered* tasks is the caller's contract
    /// (the linalg kernels hand each task exclusive tiles).
    ///
    /// # Panics
    /// Panics if the graph has a cycle (or unreachable in-degrees): the
    /// run would otherwise deadlock with work remaining. A panic inside
    /// `body` is caught, sibling workers are drained, and the panic is
    /// then propagated to the caller (never a hang).
    pub fn par_linalg(&self, graph: &TaskGraph, body: impl Fn(u32) + Sync) -> Vec<WorkerMetrics> {
        let total = graph.tasks() as u64;
        if total == 0 {
            return Vec::new();
        }
        struct State {
            /// Min-heap of ready `(priority, task)` pairs.
            ready: std::collections::BinaryHeap<std::cmp::Reverse<(u64, u32)>>,
            indegree: Vec<u32>,
            running: u32,
            done: u64,
            /// Set when a task body panicked: drain every worker so the
            /// panic can propagate through the join instead of leaving
            /// waiters parked on the condvar forever.
            aborted: bool,
        }
        let mut ready = std::collections::BinaryHeap::new();
        let indegree = graph.indegrees().to_vec();
        for (task, &deg) in indegree.iter().enumerate() {
            if deg == 0 {
                ready.push(std::cmp::Reverse((graph.priority(task as u32), task as u32)));
            }
        }
        let state =
            std::sync::Mutex::new(State { ready, indegree, running: 0, done: 0, aborted: false });
        let cv = std::sync::Condvar::new();
        let mut out: Vec<WorkerMetrics> = Vec::with_capacity(self.threads);
        std::thread::scope(|scope| {
            let mut handles = Vec::with_capacity(self.threads);
            for worker_id in 0..self.threads {
                let state = &state;
                let cv = &cv;
                let body = &body;
                handles.push(scope.spawn(move || {
                    let mut m = WorkerMetrics::new(worker_id);
                    let mut guard = state.lock().expect("scheduler state poisoned");
                    loop {
                        if guard.done == total || guard.aborted {
                            break;
                        }
                        if let Some(std::cmp::Reverse((_, task))) = guard.ready.pop() {
                            guard.running += 1;
                            drop(guard);
                            let t0 = std::time::Instant::now();
                            // Catch task panics so sibling workers can be
                            // drained before the panic propagates through
                            // the scope join (otherwise they would wait on
                            // the condvar forever).
                            let outcome = std::panic::catch_unwind(
                                std::panic::AssertUnwindSafe(|| body(task)),
                            );
                            m.record_chunk(1, t0.elapsed());
                            guard = state.lock().expect("scheduler state poisoned");
                            guard.running -= 1;
                            if let Err(payload) = outcome {
                                guard.aborted = true;
                                cv.notify_all();
                                drop(guard);
                                std::panic::resume_unwind(payload);
                            }
                            guard.done += 1;
                            let mut unlocked = false;
                            for &dep in graph.dependents(task) {
                                let deg = &mut guard.indegree[dep as usize];
                                *deg -= 1;
                                if *deg == 0 {
                                    guard
                                        .ready
                                        .push(std::cmp::Reverse((graph.priority(dep), dep)));
                                    unlocked = true;
                                }
                            }
                            if unlocked || guard.done == total {
                                cv.notify_all();
                            }
                        } else {
                            assert!(
                                guard.running > 0,
                                "par_linalg: task graph has a cycle \
                                 ({} of {total} tasks unreachable)",
                                total - guard.done
                            );
                            guard = cv.wait(guard).expect("scheduler state poisoned");
                            // Loop re-checks done/aborted before popping.
                        }
                    }
                    drop(guard);
                    m
                }));
            }
            for h in handles {
                match h.join() {
                    Ok(m) => out.push(m),
                    // Re-raise the task's own payload so callers (and
                    // #[should_panic] tests) see the original message.
                    Err(payload) => std::panic::resume_unwind(payload),
                }
            }
        });
        out
    }

    /// [`Coordinator::par_fold`] over the `2^level × 2^level` Hilbert
    /// grid (zero-allocation segments via the Figure-5 range iterator).
    pub fn par_hilbert_fold<S, I, B, M>(
        &self,
        level: u32,
        init: I,
        body: B,
        merge: M,
    ) -> (S, Vec<WorkerMetrics>)
    where
        S: Send,
        I: Fn() -> S + Sync,
        B: Fn(&mut S, u32, u32) + Sync,
        M: FnMut(S, S) -> S,
    {
        let mapper = HilbertSquare::new(level);
        self.par_fold(&mapper, init, body, merge)
    }

    /// Parallel map over an item slice: items are handed out through the
    /// same dynamic [`ChunkQueue`] the curve-segment schedulers use, so
    /// stragglers (expensive items) rebalance across workers. Results
    /// come back in input order — the generalized batching core behind
    /// [`Coordinator::par_query`], [`Coordinator::par_query_store`] and
    /// the store's per-shard probe fan-out
    /// ([`SfcStore::par_query_window`]).
    pub fn par_map<T, R>(&self, items: &[T], body: impl Fn(usize, &T) -> R + Sync) -> Vec<R>
    where
        T: Sync,
        R: Send,
    {
        if items.is_empty() {
            return Vec::new();
        }
        // Items are coarse work units: hand out small chunks so expensive
        // items don't serialize the tail.
        let chunk = (items.len() as u64).div_ceil(self.threads as u64 * 4).max(1);
        let queue = ChunkQueue::new(items.len() as u64, chunk);
        let mut shards: Vec<Vec<(usize, R)>> = Vec::with_capacity(self.threads);
        std::thread::scope(|scope| {
            let mut handles = Vec::with_capacity(self.threads);
            for _ in 0..self.threads {
                let queue = &queue;
                let body = &body;
                handles.push(scope.spawn(move || {
                    let mut local: Vec<(usize, R)> = Vec::new();
                    while let Some((start, end)) = queue.next_chunk() {
                        for i in start..end {
                            local.push((i as usize, body(i as usize, &items[i as usize])));
                        }
                    }
                    local
                }));
            }
            for h in handles {
                shards.push(h.join().expect("worker panicked"));
            }
        });
        let mut out: Vec<Option<R>> = (0..items.len()).map(|_| None).collect();
        for shard in shards {
            for (i, r) in shard {
                out[i] = Some(r);
            }
        }
        out.into_iter().map(|r| r.expect("queue covers every item")).collect()
    }

    /// Stable argsort of a `u64` key column across this coordinator's
    /// workers — the sample-sort driver of [`crate::util::sort`]
    /// (deterministic splitters, [`Coordinator::par_map`]-partitioned
    /// bucket scatter, per-bucket stable radix sort). The permutation is
    /// **bit-for-bit identical** to the serial stable sort, ties
    /// included, for any thread count; small inputs fall back to the
    /// serial radix path.
    pub fn par_argsort(&self, keys: &[u64]) -> Vec<u32> {
        crate::util::sort::sample_argsort(keys, self)
    }

    /// Answer a batch of window queries against an [`SfcIndex`] in
    /// parallel ([`Coordinator::par_map`] over the windows). Results
    /// come back in input order, each entry the ids
    /// [`SfcIndex::query_window`] would return.
    pub fn par_query(
        &self,
        index: &SfcIndex,
        windows: &[(Vec<f32>, Vec<f32>)],
    ) -> Vec<Vec<u32>> {
        self.par_map(windows, |_, (lo, hi)| index.query_window(lo, hi))
    }

    /// Answer a batch of window queries against an [`SfcStore`] in
    /// parallel, all on **one snapshot** (a consistent epoch: the whole
    /// batch sees exactly the store state at the call, however long the
    /// fan-out runs and whatever ingest lands meanwhile). Results come
    /// back in input order.
    pub fn par_query_store(
        &self,
        store: &SfcStore,
        windows: &[(Vec<f32>, Vec<f32>)],
    ) -> Vec<Vec<u32>> {
        let snap = store.snapshot();
        self.par_map(windows, |_, (lo, hi)| store.query_window_on(&snap, lo, hi))
    }

    /// Parallel map over an index range `[0, n)`: contiguous shards, one
    /// per worker. `body(worker_id, start, end)` returns a per-shard value.
    pub fn par_shards<R: Send>(
        &self,
        n: usize,
        body: impl Fn(usize, usize, usize) -> R + Sync,
    ) -> Vec<R> {
        let w = self.threads.min(n.max(1));
        let per = n.div_ceil(w.max(1));
        let mut out: Vec<R> = Vec::with_capacity(w);
        std::thread::scope(|scope| {
            let mut handles = Vec::with_capacity(w);
            for id in 0..w {
                let body = &body;
                let start = (id * per).min(n);
                let end = ((id + 1) * per).min(n);
                handles.push(scope.spawn(move || body(id, start, end)));
            }
            for h in handles {
                out.push(h.join().expect("worker panicked"));
            }
        });
        out
    }
}

/// One parallel Lloyd step: assignment sharded over contiguous point
/// ranges (each worker traverses its `(point-block × centroid-block)` grid
/// in Hilbert order), plus per-worker partial sums for the update phase.
///
/// Shards are contiguous *row* ranges, so pre-sorting the point set with
/// [`crate::apps::kmeans::hilbert_point_order`] (the d-dimensional
/// Hilbert rank) turns every shard into a spatially compact blob of the
/// full space — the CLI's `kmeans --shard hilbert` does exactly that.
///
/// Returns `(assignment, new_centroids)`.
pub fn par_kmeans_step(
    coord: &Coordinator,
    km: &KMeans,
    tp: usize,
    tc: usize,
) -> (Assignment, Matrix) {
    let n = km.points.rows;
    let k = km.centroids.rows;
    let d = km.points.cols;
    assert!(tp > 0 && tc > 0);

    struct Shard {
        start: usize,
        labels: Vec<u32>,
        dist2: Vec<f32>,
        sums: Vec<f64>,
        counts: Vec<u64>,
    }

    let shards = coord.par_shards(n, |_id, start, end| {
        let len = end - start;
        let mut labels = vec![0u32; len];
        let mut dist2 = vec![f32::INFINITY; len];
        if len > 0 {
            // Hilbert over this shard's block grid (engine rect mapper:
            // fixed-level square or FUR overlay, whichever fits).
            let pb = len.div_ceil(tp) as u32;
            let cb = k.div_ceil(tc) as u32;
            let mapper = CurveKind::Hilbert.rect_mapper(pb, cb);
            engine::for_each(mapper.as_ref(), |bp, bc| {
                let p0 = start + bp as usize * tp;
                let p1 = (p0 + tp).min(end);
                let c0 = bc as usize * tc;
                let c1 = (c0 + tc).min(k);
                for p in p0..p1 {
                    let row = km.points.row(p);
                    let (mut bd, mut bl) = (dist2[p - start], labels[p - start]);
                    for c in c0..c1 {
                        let mut s = 0.0f32;
                        for (x, y) in row.iter().zip(km.centroids.row(c)) {
                            let t = x - y;
                            s += t * t;
                        }
                        if s < bd {
                            bd = s;
                            bl = c as u32;
                        }
                    }
                    dist2[p - start] = bd;
                    labels[p - start] = bl;
                }
            });
        }
        // Partial centroid sums.
        let mut sums = vec![0.0f64; k * d];
        let mut counts = vec![0u64; k];
        for (off, &label) in labels.iter().enumerate() {
            let row = km.points.row(start + off);
            let base = label as usize * d;
            for (idx, &x) in row.iter().enumerate() {
                sums[base + idx] += x as f64;
            }
            counts[label as usize] += 1;
        }
        Shard { start, labels, dist2, sums, counts }
    });

    // Merge shards (the barrier).
    let mut labels = vec![0u32; n];
    let mut dist2 = vec![0.0f32; n];
    let mut sums = vec![0.0f64; k * d];
    let mut counts = vec![0u64; k];
    for s in shards {
        labels[s.start..s.start + s.labels.len()].copy_from_slice(&s.labels);
        dist2[s.start..s.start + s.dist2.len()].copy_from_slice(&s.dist2);
        for (a, b) in sums.iter_mut().zip(&s.sums) {
            *a += b;
        }
        for (a, b) in counts.iter_mut().zip(&s.counts) {
            *a += b;
        }
    }
    let centroids = Matrix::from_fn(k, d, |c, idx| {
        if counts[c] > 0 {
            (sums[c * d + idx] / counts[c] as f64) as f32
        } else {
            km.centroids.at(c, idx)
        }
    });
    (Assignment { labels, dist2 }, centroids)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::kmeans::{assign_naive, init_centroids, make_blobs, update_centroids};

    #[test]
    fn par_hilbert_fold_covers_grid() {
        let coord = Coordinator { threads: 4, chunk: 16 };
        let level = 5u32;
        let (count, metrics) =
            coord.par_hilbert_fold(level, || 0u64, |acc, _i, _j| *acc += 1, |a, b| a + b);
        assert_eq!(count, 1 << (2 * level));
        assert_eq!(metrics.len(), 4);
        let chunks: u64 = metrics.iter().map(|m| m.chunks).sum();
        assert_eq!(chunks, (1u64 << (2 * level)) / 16);
    }

    #[test]
    fn par_hilbert_fold_sums_match_serial() {
        let coord = Coordinator { threads: 3, chunk: 7 };
        let level = 4u32;
        let (sum, _) = coord.par_hilbert_fold(
            level,
            || 0u64,
            |acc, i, j| *acc += (i as u64) * 1000 + j as u64,
            |a, b| a + b,
        );
        let serial: u64 = crate::curves::nonrecursive::HilbertIter::with_level(level)
            .map(|(i, j)| (i as u64) * 1000 + j as u64)
            .sum();
        assert_eq!(sum, serial);
    }

    #[test]
    fn par_fold_generic_curves_match_serial() {
        let coord = Coordinator { threads: 4, chunk: 13 };
        for kind in CurveKind::ALL {
            let mapper = kind.rect_mapper(9, 21);
            let (sum, _) = coord.par_fold(
                mapper.as_ref(),
                || 0u64,
                |a, i, j| *a += (i as u64) * 1009 + j as u64,
                |a, b| a + b,
            );
            let mut serial = 0u64;
            engine::for_each(mapper.as_ref(), |i, j| serial += (i as u64) * 1009 + j as u64);
            assert_eq!(sum, serial, "{}", kind.name());
        }
    }

    #[test]
    fn par_fold_fgf_region_counts_cells() {
        use crate::curves::engine::FgfMapper;
        use crate::curves::fgf::UpperTriangle;
        let coord = Coordinator { threads: 3, chunk: 64 };
        let level = 5u32;
        let mapper = FgfMapper::new(level, UpperTriangle);
        let (count, _) =
            coord.par_fold(&mapper, || 0u64, |a, _i, _j| *a += 1, |a, b| a + b);
        let n = 1u64 << level;
        assert_eq!(count, n * (n - 1) / 2);
    }

    #[test]
    fn par_fold_nd_covers_hypercube_once() {
        use crate::curves::ndim::HilbertNd;
        let coord = Coordinator { threads: 4, chunk: 32 };
        let mapper = HilbertNd::new(3, 3); // 8×8×8
        let (sum, metrics) = coord.par_fold_nd(
            &mapper,
            || (0u64, 0u64),
            |acc, p| {
                acc.0 += 1;
                acc.1 += p.iter().enumerate().map(|(a, &c)| (a as u64 + 1) * c as u64).sum::<u64>();
            },
            |a, b| (a.0 + b.0, a.1 + b.1),
        );
        assert_eq!(sum.0, 512);
        let mut serial = (0u64, 0u64);
        engine::for_each_nd(&mapper, |p| {
            serial.0 += 1;
            serial.1 += p.iter().enumerate().map(|(a, &c)| (a as u64 + 1) * c as u64).sum::<u64>();
        });
        assert_eq!(sum, serial);
        assert_eq!(metrics.len(), 4);
    }

    #[test]
    fn par_fold_nd_accepts_blanket_adapted_2d_mappers() {
        let coord = Coordinator { threads: 3, chunk: 17 };
        let sq = HilbertSquare::new(4);
        let (nd_sum, _) = coord.par_fold_nd(
            &sq,
            || 0u64,
            |a, p| *a += (p[0] as u64) * 1009 + p[1] as u64,
            |a, b| a + b,
        );
        let (sum_2d, _) = coord.par_fold(
            &sq,
            || 0u64,
            |a, i, j| *a += (i as u64) * 1009 + j as u64,
            |a, b| a + b,
        );
        assert_eq!(nd_sum, sum_2d);
    }

    #[test]
    fn par_linalg_runs_every_task_once() {
        use std::sync::atomic::{AtomicU32, Ordering};
        let coord = Coordinator::new(4);
        let ran: Vec<AtomicU32> = (0..50).map(|_| AtomicU32::new(0)).collect();
        let graph = TaskGraph::new(50);
        let metrics = coord.par_linalg(&graph, |t| {
            ran[t as usize].fetch_add(1, Ordering::Relaxed);
        });
        assert!(ran.iter().all(|r| r.load(Ordering::Relaxed) == 1));
        let tasks: u64 = metrics.iter().map(|m| m.items).sum();
        assert_eq!(tasks, 50);
    }

    #[test]
    fn par_linalg_respects_dependency_edges() {
        use std::sync::Mutex;
        // A diamond + chain: every edge must be observed in order.
        let mut graph = TaskGraph::new(6);
        let edges = [(0u32, 1u32), (0, 2), (1, 3), (2, 3), (3, 4), (4, 5)];
        for &(b, a) in &edges {
            graph.add_dep(b, a);
        }
        for threads in [1usize, 4] {
            let coord = Coordinator::new(threads);
            let order = Mutex::new(Vec::new());
            coord.par_linalg(&graph, |t| order.lock().unwrap().push(t));
            let order = order.into_inner().unwrap();
            assert_eq!(order.len(), 6);
            let pos = |t: u32| order.iter().position(|&x| x == t).unwrap();
            for &(b, a) in &edges {
                assert!(pos(b) < pos(a), "edge {b}->{a} violated in {order:?}");
            }
        }
    }

    #[test]
    fn par_linalg_single_thread_follows_priorities() {
        use std::sync::Mutex;
        let coord = Coordinator { threads: 1, chunk: 1 };
        let mut graph = TaskGraph::new(4);
        // Reverse priorities: task 3 first, then 2, 1, 0.
        for t in 0..4u32 {
            graph.set_priority(t, 10 - t as u64);
        }
        let order = Mutex::new(Vec::new());
        coord.par_linalg(&graph, |t| order.lock().unwrap().push(t));
        assert_eq!(order.into_inner().unwrap(), vec![3, 2, 1, 0]);
    }

    #[test]
    fn par_linalg_graph_is_reusable() {
        use std::sync::atomic::{AtomicU64, Ordering};
        let mut graph = TaskGraph::new(8);
        for t in 1..8u32 {
            graph.add_dep(t - 1, t);
        }
        let coord = Coordinator::new(3);
        let count = AtomicU64::new(0);
        for _ in 0..3 {
            coord.par_linalg(&graph, |_| {
                count.fetch_add(1, Ordering::Relaxed);
            });
        }
        assert_eq!(count.load(Ordering::Relaxed), 24);
    }

    #[test]
    #[should_panic(expected = "boom")]
    fn par_linalg_task_panic_propagates_instead_of_hanging() {
        // Regression: a panicking task body must drain the waiting
        // workers and re-raise, not leave them parked on the condvar.
        let coord = Coordinator::new(4);
        let graph = TaskGraph::new(32);
        coord.par_linalg(&graph, |t| {
            if t == 7 {
                panic!("boom");
            }
        });
    }

    #[test]
    fn par_linalg_empty_graph_is_noop() {
        let coord = Coordinator::new(2);
        let metrics = coord.par_linalg(&TaskGraph::new(0), |_| unreachable!());
        assert!(metrics.is_empty());
    }

    #[test]
    fn par_query_matches_serial_windows() {
        let points = Matrix::random(600, 3, 9, 0.0, 50.0);
        let index = SfcIndex::build(&points, 6);
        let mut rng = crate::util::rng::Rng::new(77);
        let windows: Vec<(Vec<f32>, Vec<f32>)> = (0..40)
            .map(|_| {
                let lo: Vec<f32> = (0..3).map(|_| rng.f32() * 40.0).collect();
                let hi: Vec<f32> = lo.iter().map(|&l| l + rng.f32() * 15.0).collect();
                (lo, hi)
            })
            .collect();
        for threads in [1usize, 3, 8] {
            let coord = Coordinator::new(threads);
            let par = coord.par_query(&index, &windows);
            assert_eq!(par.len(), windows.len(), "threads={threads}");
            for (got, (lo, hi)) in par.iter().zip(&windows) {
                let mut want = index.query_window(lo, hi);
                let mut got = got.clone();
                want.sort_unstable();
                got.sort_unstable();
                assert_eq!(got, want, "threads={threads}");
            }
        }
    }

    #[test]
    fn par_query_empty_batch_is_empty() {
        let points = Matrix::random(10, 2, 1, 0.0, 1.0);
        let index = SfcIndex::build(&points, 4);
        assert!(Coordinator::new(2).par_query(&index, &[]).is_empty());
    }

    #[test]
    fn par_map_preserves_input_order() {
        let items: Vec<u64> = (0..257).collect();
        for threads in [1usize, 3, 8] {
            let coord = Coordinator::new(threads);
            let out = coord.par_map(&items, |i, &x| {
                assert_eq!(i as u64, x);
                x * 3 + 1
            });
            assert_eq!(out.len(), items.len(), "threads={threads}");
            for (i, &r) in out.iter().enumerate() {
                assert_eq!(r, i as u64 * 3 + 1, "threads={threads}");
            }
        }
        let empty: [u64; 0] = [];
        assert!(Coordinator::new(4).par_map(&empty, |_, &x| x).is_empty());
    }

    #[test]
    fn par_argsort_matches_serial_stable_sort() {
        let mut rng = crate::util::rng::Rng::new(4242);
        let n = (1usize << 16) + 321; // above the parallel cutover
        let keys: Vec<u64> = (0..n).map(|_| rng.below(64)).collect(); // duplicate-heavy
        let want = crate::util::sort::comparison_argsort(&keys);
        for threads in [1usize, 3, 8] {
            let coord = Coordinator::new(threads);
            assert_eq!(coord.par_argsort(&keys), want, "threads={threads}");
        }
    }

    #[test]
    fn par_query_store_matches_serial_snapshot_queries() {
        use crate::index::StoreConfig;
        let points = Matrix::random(600, 3, 9, 0.0, 50.0);
        let store = SfcStore::from_points(&points, 6, CurveKind::Hilbert, StoreConfig::default());
        let mut rng = crate::util::rng::Rng::new(77);
        let windows: Vec<(Vec<f32>, Vec<f32>)> = (0..30)
            .map(|_| {
                let lo: Vec<f32> = (0..3).map(|_| rng.f32() * 40.0).collect();
                let hi: Vec<f32> = lo.iter().map(|&l| l + rng.f32() * 15.0).collect();
                (lo, hi)
            })
            .collect();
        let snap = store.snapshot();
        for threads in [1usize, 4] {
            let coord = Coordinator::new(threads);
            let par = coord.par_query_store(&store, &windows);
            for (got, (lo, hi)) in par.iter().zip(&windows) {
                let want = store.query_window_on(&snap, lo, hi);
                assert_eq!(*got, want, "threads={threads}");
            }
        }
    }

    #[test]
    fn par_shards_cover_range_once() {
        let coord = Coordinator::new(4);
        let shards = coord.par_shards(103, |_id, s, e| (s, e));
        let mut covered = vec![false; 103];
        for (s, e) in shards {
            for x in s..e {
                assert!(!covered[x], "overlap at {x}");
                covered[x] = true;
            }
        }
        assert!(covered.iter().all(|&c| c));
    }

    #[test]
    fn par_kmeans_step_matches_serial() {
        let (points, _) = make_blobs(500, 6, 5, 0.5, 21);
        let centroids = init_centroids(&points, 6, 3);
        let km = KMeans { points, centroids };
        let serial_assign = assign_naive(&km);
        let serial_update = update_centroids(&km, &serial_assign);
        for threads in [1usize, 2, 4] {
            let coord = Coordinator::new(threads);
            let (a, c) = par_kmeans_step(&coord, &km, 64, 4);
            assert_eq!(a.labels, serial_assign.labels, "threads={threads}");
            assert!(c.max_abs_diff(&serial_update) < 1e-4, "threads={threads}");
        }
    }

    #[test]
    fn zero_threads_resolves_to_cores() {
        let c = Coordinator::new(0);
        assert!(c.threads() >= 1);
    }

    #[test]
    fn single_thread_degenerate() {
        let coord = Coordinator { threads: 1, chunk: 1_000_000 };
        let (count, _) = coord.par_hilbert_fold(3, || 0u64, |a, _, _| *a += 1, |a, b| a + b);
        assert_eq!(count, 64);
    }

    #[test]
    fn more_threads_than_items() {
        let coord = Coordinator::new(8);
        let shards = coord.par_shards(3, |_id, s, e| e - s);
        let total: usize = shards.iter().sum();
        assert_eq!(total, 3);
    }
}
