//! FGF-Hilbert loops (§6.2): jump-over of bisection quadrants for general
//! iteration regions.
//!
//! Instead of discarding out-of-region `(i,j)` pairs one by one, the
//! FGF (<u>F</u>ast <u>G</u>eneral <u>F</u>orm) traversal decides for whole
//! `2^ℓ × 2^ℓ` bisection quadrants — at any level ℓ — whether they can be
//! safely discarded. Finding the re-entry point costs `O(log n)` (the
//! quadtree descent), but arbitrarily shaped regions become iterable:
//! triangles (`i < j` pair loops), rectangles, and index-driven candidate
//! masks for the similarity join.
//!
//! Crucially (paper §6.2), the **1:1 relationship between order value and
//! coordinate pair is maintained**: skipped quadrants advance the Hilbert
//! value by `4^ℓ`, so every visited pair is reported with its *true*
//! Hilbert value `h = ℋ(i,j)` — usable as a stable pair identifier (e.g.
//! for edge lookups in graph algorithms).

use super::hilbert::{INV, STATE_D, STATE_U};

/// Classification of a bisection quadrant against a region.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum BlockClass {
    /// No cell of the block is in the region — jump over it.
    Disjoint,
    /// Every cell of the block is in the region — descend without further
    /// classification.
    Full,
    /// Mixed — descend and classify children.
    Partial,
}

/// An iteration region over the `2^L × 2^L` cover grid.
pub trait Region {
    /// Classify the `2^level × 2^level` block anchored at `(i0, j0)`.
    fn classify(&self, i0: u32, j0: u32, level: u32) -> BlockClass;

    /// Classify with the block's base Hilbert value `h0` supplied by the
    /// traversal (aligned blocks occupy `[h0, h0 + 4^level)`). Regions
    /// indexed by order value override this to skip the coordinate
    /// round-trip; the default ignores `h0`.
    #[inline]
    fn classify_h(&self, i0: u32, j0: u32, _h0: u64, level: u32) -> BlockClass {
        self.classify(i0, j0, level)
    }

    /// Cell-level membership (derived from `classify` at level 0).
    fn contains(&self, i: u32, j: u32) -> bool {
        self.classify(i, j, 0) == BlockClass::Full
    }
}

impl<'a, R: Region + ?Sized> Region for &'a R {
    fn classify(&self, i0: u32, j0: u32, level: u32) -> BlockClass {
        (**self).classify(i0, j0, level)
    }

    fn classify_h(&self, i0: u32, j0: u32, h0: u64, level: u32) -> BlockClass {
        (**self).classify_h(i0, j0, h0, level)
    }

    fn contains(&self, i: u32, j: u32) -> bool {
        (**self).contains(i, j)
    }
}

/// The strict upper triangle `i < j` — the paper's canonical example for
/// self-join pair loops (each unordered pair visited once).
#[derive(Copy, Clone, Debug)]
pub struct UpperTriangle;

impl Region for UpperTriangle {
    fn classify(&self, i0: u32, j0: u32, level: u32) -> BlockClass {
        let s = 1u64 << level;
        let (i0, j0) = (i0 as u64, j0 as u64);
        if i0 + s <= j0 {
            // max i = i0+s−1 < j0 = min j ⇒ all pairs satisfy i < j.
            BlockClass::Full
        } else if i0 + 1 >= j0 + s {
            // min i = i0 ≥ j0+s−1 = max j ⇒ no pair satisfies i < j.
            BlockClass::Disjoint
        } else {
            BlockClass::Partial
        }
    }
}

/// The inclusive lower triangle `i ≥ j` — the shape of a trailing
/// Cholesky update and of symmetric-matrix block loops.
#[derive(Copy, Clone, Debug)]
pub struct LowerTriangleIncl;

impl Region for LowerTriangleIncl {
    fn classify(&self, i0: u32, j0: u32, level: u32) -> BlockClass {
        let s = 1u64 << level;
        let (i0, j0) = (i0 as u64, j0 as u64);
        if i0 >= j0 + s - 1 {
            // min i ≥ max j ⇒ every cell has i ≥ j.
            BlockClass::Full
        } else if i0 + s <= j0 + 1 {
            // max i = i0+s−1 < … ⇒ i < j everywhere.
            BlockClass::Disjoint
        } else {
            BlockClass::Partial
        }
    }
}

/// The quarter-plane `i ≥ i_min ∧ j ≥ j_min` — composes (via
/// [`Intersect`]) into trailing-submatrix shapes.
#[derive(Copy, Clone, Debug)]
pub struct MinBounds {
    /// Minimum row (inclusive).
    pub i_min: u32,
    /// Minimum column (inclusive).
    pub j_min: u32,
}

impl Region for MinBounds {
    fn classify(&self, i0: u32, j0: u32, level: u32) -> BlockClass {
        let s = 1u64 << level;
        if (i0 as u64) + s <= self.i_min as u64 || (j0 as u64) + s <= self.j_min as u64 {
            BlockClass::Disjoint
        } else if i0 >= self.i_min && j0 >= self.j_min {
            BlockClass::Full
        } else {
            BlockClass::Partial
        }
    }
}

/// An `n×m` rectangle `{0..n} × {0..m}` — FGF's answer to non-square grids
/// (§6's overhead comparison baseline against FUR).
#[derive(Copy, Clone, Debug)]
pub struct Rect {
    /// Rows.
    pub n: u32,
    /// Columns.
    pub m: u32,
}

impl Region for Rect {
    fn classify(&self, i0: u32, j0: u32, level: u32) -> BlockClass {
        let s = 1u64 << level;
        if i0 as u64 >= self.n as u64 || j0 as u64 >= self.m as u64 {
            BlockClass::Disjoint
        } else if i0 as u64 + s <= self.n as u64 && j0 as u64 + s <= self.m as u64 {
            BlockClass::Full
        } else {
            BlockClass::Partial
        }
    }
}

/// Intersection of two regions.
#[derive(Copy, Clone, Debug)]
pub struct Intersect<A, B>(pub A, pub B);

impl<A: Region, B: Region> Region for Intersect<A, B> {
    fn classify(&self, i0: u32, j0: u32, level: u32) -> BlockClass {
        match (self.0.classify(i0, j0, level), self.1.classify(i0, j0, level)) {
            (BlockClass::Disjoint, _) | (_, BlockClass::Disjoint) => BlockClass::Disjoint,
            (BlockClass::Full, BlockClass::Full) => BlockClass::Full,
            _ => BlockClass::Partial,
        }
    }
}

/// A region defined by a per-cell predicate; blocks are always `Partial`
/// (no pruning) — the generic fallback and the "skip one-by-one" baseline
/// FGF is compared against.
pub struct PredicateRegion<F: Fn(u32, u32) -> bool>(pub F);

impl<F: Fn(u32, u32) -> bool> Region for PredicateRegion<F> {
    fn classify(&self, i0: u32, j0: u32, level: u32) -> BlockClass {
        if level == 0 {
            if (self.0)(i0, j0) {
                BlockClass::Full
            } else {
                BlockClass::Disjoint
            }
        } else {
            BlockClass::Partial
        }
    }
}

/// A coarse bitmask region: the grid is divided into `granularity ×
/// granularity` blocks (`granularity` a power of two) and a bit per block
/// marks candidate areas. This is the index-driven shape the similarity
/// join feeds FGF (paper §7): block = (cell-pair of the data-space grid
/// index).
#[derive(Clone, Debug)]
pub struct BlockMask {
    /// log2 of the block side.
    pub block_level: u32,
    /// Blocks per side.
    pub blocks: u32,
    /// Row-major bit per block.
    pub mask: Vec<bool>,
}

impl BlockMask {
    /// Create an all-false mask with `blocks × blocks` entries of side
    /// `2^block_level`.
    pub fn new(block_level: u32, blocks: u32) -> Self {
        BlockMask {
            block_level,
            blocks,
            mask: vec![false; (blocks as usize) * (blocks as usize)],
        }
    }

    /// Mark block `(bi, bj)` as candidate.
    pub fn set(&mut self, bi: u32, bj: u32) {
        self.mask[(bi * self.blocks + bj) as usize] = true;
    }

    /// Is block `(bi, bj)` marked?
    pub fn get(&self, bi: u32, bj: u32) -> bool {
        self.mask
            .get((bi * self.blocks + bj) as usize)
            .copied()
            .unwrap_or(false)
    }

    /// Fraction of marked blocks.
    pub fn density(&self) -> f64 {
        self.mask.iter().filter(|&&b| b).count() as f64 / self.mask.len() as f64
    }
}

impl Region for BlockMask {
    fn classify(&self, i0: u32, j0: u32, level: u32) -> BlockClass {
        if level >= self.block_level {
            // One or more whole mask blocks.
            let shift = level - self.block_level;
            let bi0 = (i0 >> self.block_level) as u64;
            let bj0 = (j0 >> self.block_level) as u64;
            let span = 1u64 << shift;
            let mut any = false;
            let mut all = true;
            for bi in bi0..(bi0 + span).min(self.blocks as u64) {
                for bj in bj0..(bj0 + span).min(self.blocks as u64) {
                    if self.get(bi as u32, bj as u32) {
                        any = true;
                    } else {
                        all = false;
                    }
                }
            }
            if bi0 + span > self.blocks as u64 || bj0 + span > self.blocks as u64 {
                all = false; // partially outside the mask ⇒ treat as absent
            }
            match (any, all) {
                (false, _) => BlockClass::Disjoint,
                (true, true) => BlockClass::Full,
                (true, false) => BlockClass::Partial,
            }
        } else {
            // Sub-block of one mask block.
            if self.get(i0 >> self.block_level, j0 >> self.block_level) {
                BlockClass::Full
            } else {
                BlockClass::Disjoint
            }
        }
    }
}

/// A sparse cell set indexed by **Hilbert order value** — the fast region
/// for jump-over (§Perf).
///
/// Because an aligned `2^ℓ × 2^ℓ` bisection quadrant occupies one
/// *contiguous* order-value range `[h₀, h₀ + 4^ℓ)`, classifying a block
/// against the set is a single binary search over the sorted values —
/// `O(log |set|)` instead of scanning a dense mask. This is the paper's
/// own observation (§6.2) that edges/candidates "may be facilitated by
/// determining the Hilbert values … and sorting according to the Hilbert
/// value", applied to the region test itself.
#[derive(Clone, Debug)]
pub struct HilbertSet {
    /// Sorted, deduplicated Hilbert order values (at the cover level).
    values: Vec<u64>,
    /// Cover level the values were computed at.
    pub level: u32,
}

impl HilbertSet {
    /// Build from cell coordinates on the `2^level` cover grid.
    pub fn from_cells(level: u32, cells: impl IntoIterator<Item = (u32, u32)>) -> Self {
        let mut values: Vec<u64> = cells
            .into_iter()
            .map(|(i, j)| super::hilbert::Hilbert::order_at_level(i, j, level))
            .collect();
        values.sort_unstable();
        values.dedup();
        HilbertSet { values, level }
    }

    /// Build directly from order values (must be at the same cover level).
    pub fn from_values(level: u32, mut values: Vec<u64>) -> Self {
        values.sort_unstable();
        values.dedup();
        HilbertSet { values, level }
    }

    /// Number of cells in the set.
    pub fn len(&self) -> usize {
        self.values.len()
    }

    /// True if the set is empty.
    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }
}

impl HilbertSet {
    #[inline]
    fn classify_range(&self, h0: u64, level: u32) -> BlockClass {
        let size = 1u64 << (2 * level);
        let lb = self.values.partition_point(|&v| v < h0);
        let ub = self.values.partition_point(|&v| v < h0 + size);
        let present = (ub - lb) as u64;
        if present == 0 {
            BlockClass::Disjoint
        } else if present == size {
            BlockClass::Full
        } else {
            BlockClass::Partial
        }
    }
}

impl Region for HilbertSet {
    fn classify(&self, i0: u32, j0: u32, level: u32) -> BlockClass {
        // Aligned block ⇒ contiguous order-value range.
        let size = 1u64 << (2 * level);
        let h0 = super::hilbert::Hilbert::order_at_level(i0, j0, self.level) & !(size - 1);
        self.classify_range(h0, level)
    }

    #[inline]
    fn classify_h(&self, _i0: u32, _j0: u32, h0: u64, level: u32) -> BlockClass {
        self.classify_range(h0, level)
    }
}

/// Statistics of one FGF traversal.
#[derive(Copy, Clone, Debug, Default)]
pub struct FgfStats {
    /// Pairs visited (in the region).
    pub visited: u64,
    /// Jump-over events (whole quadrants discarded), per level summed.
    pub jumps: u64,
    /// Order values skipped by jumps (= pairs *not* generated that the
    /// round-up baseline would have generated).
    pub skipped: u64,
    /// Classification calls made (the traversal's overhead measure).
    pub classifications: u64,
}

/// Run `body(i, j, h)` over every region cell of the `2^level` cover grid
/// in Hilbert order, with `h` the true Hilbert value of `(i, j)`.
pub fn fgf_hilbert_loop<R: Region>(
    level: u32,
    region: &R,
    mut body: impl FnMut(u32, u32, u64),
) -> FgfStats {
    assert!(level <= 16, "level {level} exceeds supported 16");
    let mut stats = FgfStats::default();
    let start = if level % 2 == 0 { STATE_U } else { STATE_D };
    descend(start, level, 0, 0, 0, region, false, &mut stats, &mut body);
    stats
}

#[allow(clippy::too_many_arguments)]
fn descend<R: Region>(
    state: u8,
    level: u32,
    i0: u32,
    j0: u32,
    h0: u64,
    region: &R,
    known_full: bool,
    stats: &mut FgfStats,
    body: &mut impl FnMut(u32, u32, u64),
) {
    let full = known_full || {
        stats.classifications += 1;
        match region.classify_h(i0, j0, h0, level) {
            BlockClass::Disjoint => {
                stats.jumps += 1;
                stats.skipped += 1u64 << (2 * level);
                return;
            }
            BlockClass::Full => true,
            BlockClass::Partial => false,
        }
    };
    if level == 0 {
        stats.visited += 1;
        body(i0, j0, h0);
        return;
    }
    let half = 1u32 << (level - 1);
    let step = 1u64 << (2 * (level - 1));
    for digit in 0..4u64 {
        let (ib, jb, next) = INV[state as usize][digit as usize];
        descend(
            next,
            level - 1,
            i0 + (ib as u32) * half,
            j0 + (jb as u32) * half,
            h0 + digit * step,
            region,
            full,
            stats,
            body,
        );
    }
}

/// Collect the traversal (testing/analysis helper).
pub fn fgf_path<R: Region>(level: u32, region: &R) -> (Vec<(u32, u32, u64)>, FgfStats) {
    let mut out = Vec::new();
    let stats = fgf_hilbert_loop(level, region, |i, j, h| out.push((i, j, h)));
    (out, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::curves::hilbert::Hilbert;
    use std::collections::HashSet;

    #[test]
    fn triangle_visits_exactly_i_lt_j() {
        let level = 4u32;
        let n = 1u32 << level;
        let (path, stats) = fgf_path(level, &UpperTriangle);
        let set: HashSet<(u32, u32)> = path.iter().map(|&(i, j, _)| (i, j)).collect();
        assert_eq!(set.len() as u64, stats.visited);
        let expected = (n as u64) * (n as u64 - 1) / 2;
        assert_eq!(set.len() as u64, expected);
        assert!(set.iter().all(|&(i, j)| i < j));
    }

    #[test]
    fn true_hilbert_values_maintained() {
        // Paper §6.2: the 1:1 order-value/pair relationship survives
        // jump-over.
        let (path, _) = fgf_path(5, &UpperTriangle);
        for &(i, j, h) in &path {
            assert_eq!(Hilbert::order_at_level(i, j, 5), h, "({i},{j})");
        }
        // And the h sequence is strictly increasing (Hilbert order).
        assert!(path.windows(2).all(|w| w[0].2 < w[1].2));
    }

    #[test]
    fn rect_region_matches_grid() {
        let (n, m) = (10u32, 23u32);
        let level = 5u32; // 32×32 cover
        let (path, stats) = fgf_path(level, &Rect { n, m });
        assert_eq!(path.len(), (n * m) as usize);
        assert!(path.iter().all(|&(i, j, _)| i < n && j < m));
        assert!(stats.skipped > 0, "must jump over out-of-rect quadrants");
    }

    #[test]
    fn jump_over_beats_per_cell_filtering() {
        // FGF's point: the predicate baseline classifies every cell of the
        // cover grid; jump-over classifies a logarithmic envelope.
        let level = 6u32;
        let rect = Rect { n: 7, m: 60 };
        let (_, smart) = fgf_path(level, &rect);
        let pred = PredicateRegion(|i, j| i < 7 && j < 60);
        let (_, dumb) = fgf_path(level, &pred);
        assert_eq!(smart.visited, dumb.visited);
        assert!(
            smart.classifications < dumb.classifications / 4,
            "jump-over {} vs per-cell {}",
            smart.classifications,
            dumb.classifications
        );
    }

    #[test]
    fn full_grid_region_equals_plain_hilbert() {
        let level = 3u32;
        let n = 1u32 << level;
        let (path, stats) = fgf_path(level, &Rect { n, m: n });
        let plain: Vec<_> = crate::curves::nonrecursive::HilbertIter::with_level(level).collect();
        let got: Vec<_> = path.iter().map(|&(i, j, _)| (i, j)).collect();
        assert_eq!(got, plain);
        assert_eq!(stats.jumps, 0);
    }

    #[test]
    fn intersect_region() {
        let level = 4u32;
        let r = Intersect(UpperTriangle, Rect { n: 8, m: 12 });
        let (path, _) = fgf_path(level, &r);
        assert!(path.iter().all(|&(i, j, _)| i < j && i < 8 && j < 12));
        let brute = (0..8u32)
            .flat_map(|i| (0..12u32).map(move |j| (i, j)))
            .filter(|&(i, j)| i < j)
            .count();
        assert_eq!(path.len(), brute);
    }

    #[test]
    fn lower_triangle_incl_complements_upper() {
        let level = 4u32;
        let n = 1u32 << level;
        let (lower, _) = fgf_path(level, &LowerTriangleIncl);
        let (upper, _) = fgf_path(level, &UpperTriangle);
        assert_eq!(lower.len() + upper.len(), (n as usize) * (n as usize));
        assert!(lower.iter().all(|&(i, j, _)| i >= j));
    }

    #[test]
    fn min_bounds_trailing_shape() {
        let r = Intersect(LowerTriangleIncl, MinBounds { i_min: 3, j_min: 3 });
        let (path, stats) = fgf_path(4, &r);
        assert!(path.iter().all(|&(i, j, _)| i >= j && i >= 3 && j >= 3));
        let brute = (3..16u32).map(|i| i - 3 + 1).sum::<u32>() as usize;
        assert_eq!(path.len(), brute);
        assert!(stats.jumps > 0);
    }

    #[test]
    fn block_mask_region() {
        let mut mask = BlockMask::new(2, 4); // 4×4 blocks of 4×4 cells = 16×16 grid
        mask.set(0, 0);
        mask.set(2, 3);
        let (path, _) = fgf_path(4, &mask);
        assert_eq!(path.len(), 2 * 16);
        assert!(path
            .iter()
            .all(|&(i, j, _)| (i < 4 && j < 4) || ((8..12).contains(&i) && (12..16).contains(&j))));
    }

    #[test]
    fn hilbert_set_equals_block_mask_traversal() {
        // HilbertSet and BlockMask(level 0) define the same region; the
        // traversals must visit identical cells in identical order.
        let level = 5u32;
        let mut mask = BlockMask::new(0, 1 << level);
        let cells = [(3u32, 7u32), (0, 0), (31, 31), (12, 13), (12, 14), (13, 13)];
        for &(i, j) in &cells {
            mask.set(i, j);
        }
        let set = HilbertSet::from_cells(level, cells.iter().copied());
        assert_eq!(set.len(), cells.len());
        let (a, _) = fgf_path(level, &mask);
        let (b, _) = fgf_path(level, &set);
        assert_eq!(a, b);
    }

    #[test]
    fn hilbert_set_classify_is_consistent() {
        use crate::util::check::forall_seeded;
        forall_seeded::<(u32, u32)>("hilbertset-consistency", 77, 64, |&(seed, _)| {
            let level = 4u32;
            let side = 1u32 << level;
            let mut rng = crate::util::rng::Rng::new(seed as u64);
            let cells: Vec<(u32, u32)> = (0..20)
                .map(|_| (rng.below(side as u64) as u32, rng.below(side as u64) as u32))
                .collect();
            let set = HilbertSet::from_cells(level, cells.iter().copied());
            let inset: std::collections::HashSet<_> = cells.iter().copied().collect();
            let (path, _) = fgf_path(level, &set);
            let visited: std::collections::HashSet<_> =
                path.iter().map(|&(i, j, _)| (i, j)).collect();
            visited == inset
        });
    }

    #[test]
    fn hilbert_set_full_grid_is_full() {
        let level = 3u32;
        let side = 1u32 << level;
        let all: Vec<(u32, u32)> =
            (0..side).flat_map(|i| (0..side).map(move |j| (i, j))).collect();
        let set = HilbertSet::from_cells(level, all);
        assert_eq!(set.classify(0, 0, level), BlockClass::Full);
        let (_, stats) = fgf_path(level, &set);
        assert_eq!(stats.jumps, 0);
        assert!(HilbertSet::from_cells(3, std::iter::empty()).is_empty());
    }

    #[test]
    fn block_mask_density() {
        let mut mask = BlockMask::new(1, 2);
        assert_eq!(mask.density(), 0.0);
        mask.set(0, 1);
        assert_eq!(mask.density(), 0.25);
    }

    #[test]
    fn stats_account_for_all_values() {
        // visited + skipped = 4^level: every order value is either visited
        // or jumped, never both.
        let level = 5u32;
        let (_, stats) = fgf_path(level, &UpperTriangle);
        assert_eq!(stats.visited + stats.skipped, 1u64 << (2 * level));
    }

    #[test]
    fn empty_region() {
        let (path, stats) = fgf_path(4, &Rect { n: 0, m: 10 });
        assert!(path.is_empty());
        assert_eq!(stats.visited, 0);
        assert_eq!(stats.skipped, 256);
    }
}
