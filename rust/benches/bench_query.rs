//! Range-query bench (ISSUE 3): window→range decomposition and
//! `SfcIndex` query latency for Hilbert vs Z-order vs canonic at
//! d ∈ {2, 3}, against the full-scan baseline. Emits JSON
//! (`reports/bench_query.json`) for the perf trajectory.
//!
//! Expected shape: Hilbert's clustering property yields the fewest
//! ranges-per-window (strictly below Z-order — the ISSUE 3 acceptance
//! check, asserted here), and decomposition + binary search beats the
//! full scan by orders of magnitude at low selectivity.

use sfc_mine::apps::simjoin::{
    join_sfc_decompose_dims, join_sfc_dims, make_clustered, normalize,
};
use sfc_mine::curves::engine::{CurveMapperNd, WindowNd};
use sfc_mine::curves::CurveKind;
use sfc_mine::index::SfcIndex;
use sfc_mine::util::bench::Bench;
use sfc_mine::util::rng::Rng;
use sfc_mine::util::table::Table;

fn write_json(bench: &Bench, path: &str) -> std::io::Result<()> {
    let mut s = String::from("[\n");
    for (idx, m) in bench.results().iter().enumerate() {
        if idx > 0 {
            s.push_str(",\n");
        }
        s.push_str(&format!(
            "  {{\"name\": \"{}\", \"median_ns\": {}, \"mad_ns\": {}, \"elements\": {}}}",
            m.name,
            m.median.as_nanos(),
            m.mad.as_nanos(),
            m.elements.unwrap_or(0)
        ));
    }
    s.push_str("\n]\n");
    if let Some(dir) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(path, s)
}

/// Random inclusive cell windows at `frac` of the cube side.
fn random_windows(count: usize, dims: usize, side: u32, frac: f64, seed: u64) -> Vec<WindowNd> {
    let mut rng = Rng::new(seed);
    let half = ((side as f64 * frac) as u32).max(1);
    (0..count)
        .map(|_| {
            let lo: Vec<u32> = (0..dims)
                .map(|_| rng.below(side.saturating_sub(half) as u64 + 1) as u32)
                .collect();
            let hi: Vec<u32> = lo.iter().map(|&l| (l + half).min(side - 1)).collect();
            WindowNd::new(lo, hi)
        })
        .collect()
}

fn main() {
    let fast = std::env::var("SFC_BENCH_FAST").is_ok();
    let n_points: usize = if fast { 4_000 } else { 40_000 };
    let n_windows: usize = if fast { 48 } else { 256 };
    let mut bench = Bench::new();

    // --- window→range decomposition: ranges-per-window + latency --------
    let mut table = Table::new(vec![
        "dims",
        "curve",
        "level",
        "mean ranges/window",
        "decompose µs/window",
    ]);
    let mut level8_means: Vec<(CurveKind, f64)> = Vec::new();
    for dims in [2usize, 3] {
        let level = 8u32;
        let side = 1u32 << level;
        let windows = random_windows(n_windows, dims, side, 0.08, 7 + dims as u64);
        for kind in [CurveKind::Hilbert, CurveKind::ZOrder, CurveKind::Canonic] {
            let mapper = kind.nd_mapper(dims, level);
            let total_ranges: u64 = windows
                .iter()
                .map(|w| mapper.decompose_nd(w).len() as u64)
                .sum();
            let mean = total_ranges as f64 / windows.len() as f64;
            let m = bench.throughput(
                &format!("query/decompose/{}/d{dims}", kind.name()),
                windows.len() as u64,
                || {
                    let mut acc = 0usize;
                    for w in &windows {
                        acc += mapper.decompose_nd(w).len();
                    }
                    acc
                },
            );
            table.row(vec![
                dims.to_string(),
                kind.name().to_string(),
                level.to_string(),
                format!("{mean:.1}"),
                format!("{:.2}", m.median.as_nanos() as f64 / 1e3 / windows.len() as f64),
            ]);
            if dims == 2 {
                level8_means.push((kind, mean));
            }
        }
    }
    println!("\nwindow decomposition (mean over {n_windows} random windows):");
    print!("{}", table.render());

    // The ISSUE 3 acceptance check, enforced at bench time: Hilbert's
    // clustering property must beat Z-order on 2-D level-8 windows.
    let hilbert = level8_means
        .iter()
        .find(|(k, _)| *k == CurveKind::Hilbert)
        .unwrap()
        .1;
    let zorder = level8_means
        .iter()
        .find(|(k, _)| *k == CurveKind::ZOrder)
        .unwrap()
        .1;
    assert!(
        hilbert < zorder,
        "clustering property violated: hilbert {hilbert:.1} ranges/window vs zorder {zorder:.1}"
    );
    println!(
        "clustering property (d=2, level 8): hilbert {hilbert:.1} vs zorder {zorder:.1} \
         ranges/window ({:.2}x fewer)\n",
        zorder / hilbert
    );

    // --- SfcIndex window queries vs full scan ---------------------------
    let mut qtable = Table::new(vec!["dims", "variant", "µs/query", "speedup vs scan"]);
    for dims in [2usize, 3] {
        let points = make_clustered(n_points, dims, 40, 0.8, 11);
        let (min, max) = sfc_mine::index::axis_bounds(&points, dims).unwrap();
        let mut rng = Rng::new(23);
        let queries: Vec<(Vec<f32>, Vec<f32>)> = (0..n_windows)
            .map(|_| {
                let p = rng.below(n_points as u64) as usize;
                let lo: Vec<f32> = (0..dims)
                    .map(|a| points.at(p, a) - 0.05 * (max[a] - min[a]))
                    .collect();
                let hi: Vec<f32> = (0..dims)
                    .map(|a| points.at(p, a) + 0.05 * (max[a] - min[a]))
                    .collect();
                (lo, hi)
            })
            .collect();
        let m_scan = bench.throughput(&format!("query/scan/d{dims}"), n_windows as u64, || {
            let mut acc = 0usize;
            for (lo, hi) in &queries {
                for p in 0..points.rows {
                    let row = points.row(p);
                    if row
                        .iter()
                        .zip(lo.iter().zip(hi))
                        .all(|(&v, (&l, &h))| (l..=h).contains(&v))
                    {
                        acc += 1;
                    }
                }
            }
            acc
        });
        qtable.row(vec![
            dims.to_string(),
            "full-scan".to_string(),
            format!("{:.2}", m_scan.median.as_nanos() as f64 / 1e3 / n_windows as f64),
            "1.0x".to_string(),
        ]);
        for kind in [CurveKind::Hilbert, CurveKind::ZOrder, CurveKind::Canonic] {
            let index = SfcIndex::build_with(&points, 8, kind);
            let m = bench.throughput(
                &format!("query/window/{}/d{dims}", kind.name()),
                n_windows as u64,
                || {
                    let mut acc = 0usize;
                    for (lo, hi) in &queries {
                        acc += index.query_window(lo, hi).len();
                    }
                    acc
                },
            );
            qtable.row(vec![
                dims.to_string(),
                format!("sfc-index/{}", kind.name()),
                format!("{:.2}", m.median.as_nanos() as f64 / 1e3 / n_windows as f64),
                format!(
                    "{:.1}x",
                    m_scan.median.as_secs_f64() / m.median.as_secs_f64()
                ),
            ]);
        }
    }
    println!("\nwindow queries over {n_points} clustered points:");
    print!("{}", qtable.render());

    write_json(&bench, "reports/bench_query.json").expect("write bench JSON");
    println!("\nwrote reports/bench_query.json");

    // --- neighbor jumps vs per-cell window decomposition (ISSUE 7) ------
    // Both kNN drivers and both simjoin drivers must return bit-for-bit
    // identical results; the neighbor paths must issue strictly fewer
    // key probes. Asserted here so a regression fails the bench run.
    struct NeighborRec {
        name: String,
        median_ns: u128,
        key_probes: u64,
    }
    let mut recs: Vec<NeighborRec> = Vec::new();
    let knn_k = 8usize;
    let mut ktable = Table::new(vec!["dims", "kNN driver", "µs/query", "key probes/query"]);
    for dims in [2usize, 3] {
        let points = make_clustered(n_points, dims, 40, 0.8, 17);
        let index = SfcIndex::build_with(&points, 6, CurveKind::Hilbert);
        assert!(index.neighbor_path().is_fast(), "Hilbert must walk the automaton");
        let mut rng = Rng::new(31 + dims as u64);
        let queries: Vec<Vec<f32>> = (0..n_windows)
            .map(|_| {
                let p = rng.below(n_points as u64) as usize;
                points.row(p).iter().map(|&v| v + 0.3).collect()
            })
            .collect();
        let (mut fp, mut lp) = (0u64, 0u64);
        let mut frontier_hits = Vec::with_capacity(queries.len());
        for q in &queries {
            let (h, s) = index.query_knn_stats(q, knn_k);
            fp += s.key_probes;
            frontier_hits.push(h);
        }
        for (q, fh) in queries.iter().zip(&frontier_hits) {
            let (h, s) = index.query_knn_legacy_stats(q, knn_k);
            lp += s.key_probes;
            assert_eq!(&h, fh, "frontier kNN must equal the legacy driver bit for bit");
        }
        assert!(
            fp < lp,
            "frontier kNN must probe strictly less: {fp} vs legacy {lp} (d={dims})"
        );
        let m_f = bench.throughput(
            &format!("neighbor/knn-frontier/d{dims}"),
            n_windows as u64,
            || {
                let mut acc = 0usize;
                for q in &queries {
                    acc += index.query_knn(q, knn_k).len();
                }
                acc
            },
        );
        let m_l = bench.throughput(
            &format!("neighbor/knn-legacy/d{dims}"),
            n_windows as u64,
            || {
                let mut acc = 0usize;
                for q in &queries {
                    acc += index.query_knn_legacy(q, knn_k).len();
                }
                acc
            },
        );
        let per_q = |ns: u128| ns as f64 / 1e3 / n_windows as f64;
        ktable.row(vec![
            dims.to_string(),
            "frontier (neighbor jumps)".to_string(),
            format!("{:.2}", per_q(m_f.median.as_nanos())),
            format!("{:.1}", fp as f64 / n_windows as f64),
        ]);
        ktable.row(vec![
            dims.to_string(),
            "legacy (expanding window)".to_string(),
            format!("{:.2}", per_q(m_l.median.as_nanos())),
            format!("{:.1}", lp as f64 / n_windows as f64),
        ]);
        recs.push(NeighborRec {
            name: format!("neighbor/knn-frontier/d{dims}"),
            median_ns: m_f.median.as_nanos(),
            key_probes: fp,
        });
        recs.push(NeighborRec {
            name: format!("neighbor/knn-legacy/d{dims}"),
            median_ns: m_l.median.as_nanos(),
            key_probes: lp,
        });
    }
    println!("\nfrontier kNN vs legacy expanding window (k={knn_k}, {n_windows} queries):");
    print!("{}", ktable.render());

    let n_join: usize = if fast { 1_500 } else { 8_000 };
    let mut jtable = Table::new(vec!["dims", "simjoin driver", "ms", "key probes", "pairs"]);
    for dims in [2usize, 3] {
        let jp = make_clustered(n_join, dims, 30, 0.8, 29);
        let eps = 0.8f32;
        let (pj, sj) = join_sfc_dims(&jp, eps, dims);
        let (pd, sd) = join_sfc_decompose_dims(&jp, eps, dims);
        assert_eq!(
            normalize(pj.clone()),
            normalize(pd),
            "jump join must equal decomposition bit for bit (d={dims})"
        );
        assert_eq!(sj.comparisons, sd.comparisons, "identical candidate structure");
        assert!(
            sj.key_probes < sd.key_probes,
            "jump join must probe strictly less: {} vs {} (d={dims})",
            sj.key_probes,
            sd.key_probes
        );
        let m_j = bench.run(&format!("neighbor/join-jump/d{dims}"), || {
            join_sfc_dims(&jp, eps, dims).0.len()
        });
        let m_d = bench.run(&format!("neighbor/join-decompose/d{dims}"), || {
            join_sfc_decompose_dims(&jp, eps, dims).0.len()
        });
        jtable.row(vec![
            dims.to_string(),
            "stencil jumps".to_string(),
            format!("{:.2}", m_j.median.as_nanos() as f64 / 1e6),
            sj.key_probes.to_string(),
            pj.len().to_string(),
        ]);
        jtable.row(vec![
            dims.to_string(),
            "window decompose".to_string(),
            format!("{:.2}", m_d.median.as_nanos() as f64 / 1e6),
            sd.key_probes.to_string(),
            pj.len().to_string(),
        ]);
        recs.push(NeighborRec {
            name: format!("neighbor/join-jump/d{dims}"),
            median_ns: m_j.median.as_nanos(),
            key_probes: sj.key_probes,
        });
        recs.push(NeighborRec {
            name: format!("neighbor/join-decompose/d{dims}"),
            median_ns: m_d.median.as_nanos(),
            key_probes: sd.key_probes,
        });
    }
    println!("\nsimjoin: stencil jumps vs window decomposition ({n_join} points, eps 0.8):");
    print!("{}", jtable.render());

    let mut s = String::from("[\n");
    for (idx, r) in recs.iter().enumerate() {
        if idx > 0 {
            s.push_str(",\n");
        }
        s.push_str(&format!(
            "  {{\"name\": \"{}\", \"median_ns\": {}, \"key_probes\": {}}}",
            r.name, r.median_ns, r.key_probes
        ));
    }
    s.push_str("\n]\n");
    std::fs::create_dir_all("reports").expect("create reports dir");
    std::fs::write("reports/bench_neighbor.json", s).expect("write neighbor bench JSON");
    println!("\nwrote reports/bench_neighbor.json");
}
