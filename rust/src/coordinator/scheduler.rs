//! Curve-segment scheduling: hand out contiguous Hilbert-order ranges.
//!
//! Contiguity is the point — a contiguous order-value range is a spatially
//! compact blob of the grid (the Hilbert curve's defining property), so a
//! worker that processes one chunk end-to-end enjoys the same locality the
//! serial loop would.

use std::sync::atomic::{AtomicU64, Ordering};

/// Dynamic chunk queue over the order-value range `[0, total)`.
///
/// Lock-free: a single atomic cursor; each `next_chunk` claims the next
/// `chunk`-sized contiguous segment.
#[derive(Debug)]
pub struct ChunkQueue {
    cursor: AtomicU64,
    total: u64,
    chunk: u64,
}

impl ChunkQueue {
    /// Queue over `[0, total)` with the given chunk size (≥ 1).
    pub fn new(total: u64, chunk: u64) -> Self {
        assert!(chunk >= 1, "chunk size must be ≥ 1");
        ChunkQueue { cursor: AtomicU64::new(0), total, chunk }
    }

    /// Claim the next chunk; `None` once the range is exhausted.
    ///
    /// Compare-exchange rather than an unconditional `fetch_add`: the
    /// cursor is clamped at `total`, so a long-lived queue polled after
    /// exhaustion can never advance the atomic further (an unconditional
    /// add would keep growing it and could in principle wrap `u64` and
    /// restart the range), and [`ChunkQueue::remaining`] stays exact.
    #[inline]
    pub fn next_chunk(&self) -> Option<(u64, u64)> {
        let mut start = self.cursor.load(Ordering::Relaxed);
        loop {
            if start >= self.total {
                return None;
            }
            let end = (start + self.chunk).min(self.total);
            match self
                .cursor
                .compare_exchange_weak(start, end, Ordering::Relaxed, Ordering::Relaxed)
            {
                Ok(_) => return Some((start, end)),
                Err(seen) => start = seen,
            }
        }
    }

    /// Remaining order values (exact: the cursor never exceeds `total`).
    pub fn remaining(&self) -> u64 {
        self.total.saturating_sub(self.cursor.load(Ordering::Relaxed))
    }
}

/// A dependency graph over numbered tasks, scheduled by
/// [`Coordinator::par_linalg`](crate::coordinator::Coordinator::par_linalg).
///
/// Each task carries a **priority** (lower runs first among ready tasks);
/// the linear-algebra kernels set it to the task's tile **curve order
/// value**, so whenever several tasks are runnable the scheduler picks the
/// one whose working set is spatially closest to recently-finished work —
/// the locality-preserving hand-out of [`ChunkQueue`], generalized to
/// DAG-constrained task spaces (left-looking Cholesky panels, wavefront
/// rounds).
///
/// Edges are added with [`TaskGraph::add_dep`]; a task becomes ready when
/// every predecessor has finished. Graphs are reusable: the executor
/// copies the in-degree vector per run.
#[derive(Clone, Debug)]
pub struct TaskGraph {
    priority: Vec<u64>,
    dependents: Vec<Vec<u32>>,
    indegree: Vec<u32>,
    edges: u64,
}

impl TaskGraph {
    /// Graph of `tasks` initially-independent tasks, priorities defaulting
    /// to the task index (so tasks created in curve order run in curve
    /// order).
    pub fn new(tasks: usize) -> Self {
        assert!(tasks <= u32::MAX as usize, "task ids are u32");
        TaskGraph {
            priority: (0..tasks as u64).collect(),
            dependents: vec![Vec::new(); tasks],
            indegree: vec![0; tasks],
            edges: 0,
        }
    }

    /// Number of tasks.
    pub fn tasks(&self) -> usize {
        self.priority.len()
    }

    /// Number of dependency edges.
    pub fn edges(&self) -> u64 {
        self.edges
    }

    /// Set a task's scheduling priority (lower runs first among ready
    /// tasks); linalg kernels pass the tile's curve order value.
    pub fn set_priority(&mut self, task: u32, priority: u64) {
        self.priority[task as usize] = priority;
    }

    /// Scheduling priority of a task.
    pub fn priority(&self, task: u32) -> u64 {
        self.priority[task as usize]
    }

    /// Declare that `after` may only run once `before` has finished.
    /// Duplicate edges are permitted (counted consistently on both sides).
    pub fn add_dep(&mut self, before: u32, after: u32) {
        assert_ne!(before, after, "a task cannot depend on itself");
        self.dependents[before as usize].push(after);
        self.indegree[after as usize] += 1;
        self.edges += 1;
    }

    /// Tasks unlocked by `task` finishing.
    pub(crate) fn dependents(&self, task: u32) -> &[u32] {
        &self.dependents[task as usize]
    }

    /// Initial in-degree of every task (copied per run by the executor).
    pub(crate) fn indegrees(&self) -> &[u32] {
        &self.indegree
    }
}

/// Static partition of `[0, total)` into `parts` near-equal contiguous
/// ranges (the zero-coordination alternative to [`ChunkQueue`]).
pub fn static_ranges(total: u64, parts: usize) -> Vec<(u64, u64)> {
    assert!(parts >= 1);
    let parts = parts as u64;
    let base = total / parts;
    let rem = total % parts;
    let mut out = Vec::with_capacity(parts as usize);
    let mut start = 0u64;
    for p in 0..parts {
        let len = base + u64::from(p < rem);
        out.push((start, start + len));
        start += len;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_partition_range() {
        let q = ChunkQueue::new(100, 7);
        let mut seen = vec![false; 100];
        while let Some((s, e)) = q.next_chunk() {
            for x in s..e {
                assert!(!seen[x as usize], "duplicate at {x}");
                seen[x as usize] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn concurrent_claims_are_disjoint() {
        let q = ChunkQueue::new(10_000, 13);
        let mut claimed: Vec<(u64, u64)> = Vec::new();
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..8)
                .map(|_| {
                    scope.spawn(|| {
                        let mut mine = Vec::new();
                        while let Some(c) = q.next_chunk() {
                            mine.push(c);
                        }
                        mine
                    })
                })
                .collect();
            for h in handles {
                claimed.extend(h.join().unwrap());
            }
        });
        claimed.sort_unstable();
        let total: u64 = claimed.iter().map(|&(s, e)| e - s).sum();
        assert_eq!(total, 10_000);
        for w in claimed.windows(2) {
            assert_eq!(w[0].1, w[1].0, "gap or overlap between {:?} and {:?}", w[0], w[1]);
        }
    }

    #[test]
    fn remaining_decreases() {
        let q = ChunkQueue::new(20, 10);
        assert_eq!(q.remaining(), 20);
        q.next_chunk();
        assert_eq!(q.remaining(), 10);
    }

    #[test]
    fn exhausted_queue_cursor_stays_clamped() {
        // Polling an exhausted queue must not advance the cursor (the old
        // unconditional fetch_add kept growing it, so a long-lived queue
        // could in principle wrap u64 and hand out the range again).
        let q = ChunkQueue::new(25, 10);
        while q.next_chunk().is_some() {}
        for _ in 0..1000 {
            assert_eq!(q.next_chunk(), None);
            assert_eq!(q.remaining(), 0);
        }
        assert_eq!(q.cursor.load(std::sync::atomic::Ordering::Relaxed), 25);
    }

    #[test]
    fn final_chunk_is_clamped_to_total() {
        let q = ChunkQueue::new(25, 10);
        assert_eq!(q.next_chunk(), Some((0, 10)));
        assert_eq!(q.next_chunk(), Some((10, 20)));
        assert_eq!(q.next_chunk(), Some((20, 25)));
        assert_eq!(q.next_chunk(), None);
    }

    #[test]
    fn task_graph_counts_edges_and_degrees() {
        let mut g = TaskGraph::new(4);
        assert_eq!(g.tasks(), 4);
        assert_eq!(g.edges(), 0);
        g.add_dep(0, 2);
        g.add_dep(1, 2);
        g.add_dep(2, 3);
        assert_eq!(g.edges(), 3);
        assert_eq!(g.indegrees(), &[0, 0, 2, 1]);
        assert_eq!(g.dependents(0), &[2]);
        assert_eq!(g.dependents(2), &[3]);
        g.set_priority(3, 99);
        assert_eq!(g.priority(3), 99);
        assert_eq!(g.priority(1), 1, "default priority is the task index");
    }

    #[test]
    #[should_panic(expected = "cannot depend on itself")]
    fn task_graph_rejects_self_edges() {
        TaskGraph::new(2).add_dep(1, 1);
    }

    #[test]
    fn static_ranges_cover() {
        for (total, parts) in [(100u64, 3usize), (7, 10), (0, 2), (64, 64)] {
            let ranges = static_ranges(total, parts);
            assert_eq!(ranges.len(), parts);
            let sum: u64 = ranges.iter().map(|&(s, e)| e - s).sum();
            assert_eq!(sum, total);
            for w in ranges.windows(2) {
                assert_eq!(w[0].1, w[1].0);
            }
            // Near-equal: lengths differ by at most 1.
            let lens: Vec<u64> = ranges.iter().map(|&(s, e)| e - s).collect();
            let (mn, mx) = (lens.iter().min().unwrap(), lens.iter().max().unwrap());
            assert!(mx - mn <= 1);
        }
    }
}
