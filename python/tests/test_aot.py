"""AOT path: lowering emits parseable HLO text with a tuple root."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp

from compile import aot, model

jax.config.update("jax_platform_name", "cpu")


def test_hlo_text_structure():
    hlo = aot.to_hlo_text(model.matmul_tuple, aot.f32(16, 16), aot.f32(16, 16))
    assert "HloModule" in hlo
    assert "ENTRY" in hlo
    assert "f32[16,16]" in hlo
    # Tuple root: the entry computation returns a tuple type.
    assert "ROOT tuple" in hlo


def test_kmeans_step_hlo_has_four_outputs():
    hlo = aot.to_hlo_text(model.kmeans_step_tuple, aot.f32(64, 4), aot.f32(8, 4))
    assert "HloModule" in hlo
    # Root tuple of four f32 results: labels(64), counts(8), sums(8,4), inertia().
    assert "f32[64]" in hlo and "f32[8]" in hlo and "f32[8,4]" in hlo


def test_cli_writes_artifacts(tmp_path):
    out = tmp_path / "artifacts"
    env = dict(os.environ)
    repo_python = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = repo_python
    subprocess.run(
        [
            sys.executable,
            "-m",
            "compile.aot",
            "--out-dir",
            str(out),
            "--kmeans-n",
            "32",
            "--kmeans-d",
            "4",
            "--kmeans-k",
            "8",
            "--matmul-n",
            "16",
            "--matmul-k",
            "16",
            "--matmul-m",
            "16",
        ],
        check=True,
        cwd=repo_python,
        env=env,
    )
    names = sorted(p.name for p in out.iterdir())
    assert "manifest.txt" in names
    assert "kmeans_step.hlo.txt" in names
    assert "matmul.hlo.txt" in names
    assert "pairwise_dists.hlo.txt" in names
    manifest = (out / "manifest.txt").read_text()
    for line in manifest.splitlines():
        if line.startswith("#") or not line.strip():
            continue
        name, fname, _comment = line.split("\t", 2)
        assert (out / fname).exists(), f"{name} file missing"
