//! # sfc-mine — Space-filling Curves for High-performance Data Mining
//!
//! A reproduction of Böhm, *"Space-filling Curves for High-performance Data
//! Mining"* (2020) as a production-grade library.
//!
//! ## Architecture: one engine, many curves
//!
//! The paper's central idea is that a single abstraction — a bijective
//! order mapping `C(i,j) ⇄ c` — drives every application. The codebase
//! mirrors that: the **[`curves::engine`]** module is the single entry
//! point, an object-safe [`CurveMapper`] interface that every layer above
//! the curves dispatches through:
//!
//! * [`curves`] — the curve toolkit behind the engine: Z-order, Hilbert
//!   (Mealy automaton, recursive Lindenmayer grammar, non-recursive
//!   constant-overhead generator), Gray-code, Peano, FUR-Hilbert loops
//!   over arbitrary `n×m` grids, FGF-Hilbert jump-over for general
//!   regions, and nano-programs. Pick a mapper with
//!   [`curves::CurveKind::mapper`] (full plane),
//!   [`curves::CurveKind::rect_mapper`] (any rectangle, contiguous order
//!   values) or [`curves::CurveKind::nd_mapper`] (**d-dimensional**
//!   hypercubes — [`curves::ndim`] holds the native d-dim Z-order,
//!   Gray-code, Butz/Lawder Hilbert and Peano curves, and an adapter
//!   makes every 2-D mapper a [`CurveMapperNd`] with
//!   `dims() == 2`); batched `order_batch`/`coords_batch` (and their
//!   `_nd` twins) amortise automaton state across runs.
//! * [`coordinator`] — the MIMD runtime: [`coordinator::Coordinator::par_fold`]
//!   schedules **contiguous curve segments** of any finite-domain mapper
//!   across a worker pool, preserving locality per worker;
//!   [`coordinator::Coordinator::par_fold_nd`] does the same for
//!   d-dimensional domains through the identical chunk queue.
//! * [`apps`] — the paper's §7 application suite: matrix multiplication,
//!   Cholesky decomposition, Floyd–Warshall, k-Means (with d-dim Hilbert
//!   point sharding via [`apps::kmeans::hilbert_point_order`]), and the
//!   ε-similarity join, each in canonic, cache-conscious (tiled) and
//!   cache-oblivious (engine-curve) variants.
//! * [`linalg`] — cache-oblivious linear algebra (§6–§7):
//!   [`linalg::TiledMatrix`] stores `tile × tile` blocks contiguously in
//!   curve order; the matmul/Cholesky/Floyd kernels run on it
//!   sequentially or as dependency graphs through
//!   [`coordinator::Coordinator::par_linalg`] (bitwise equal either
//!   way), and [`linalg::sim`] replays each variant's access stream
//!   through the cache simulator for per-matrix L1/L2 miss reports.
//! * [`index`] — the index substrates: the legacy 2-D projection
//!   [`index::GridIndex`], the full-dimensional [`index::GridIndexNd`]
//!   (cells ranked along the true d-dim Hilbert curve), the
//!   order-sorted [`index::SfcIndex`] serving point/window/kNN queries
//!   by decomposing each window into contiguous curve ranges
//!   ([`CurveMapperNd::decompose_nd`]) and binary-searching its sorted
//!   key column — the paper's "search structures" application — and the
//!   **serving layer** built from the same pieces: [`index::SfcStore`],
//!   a sharded, mutable store of curve-key-sorted LSM segments with
//!   lock-free-for-readers snapshot queries, a range-routed query
//!   planner (decompose once, cut at the curve-order shard fenceposts)
//!   and equi-depth shard rebalancing. The async serving pipeline on
//!   top ([`index::IngestPipeline`]) batches and backpressures
//!   concurrent insert/delete/expire producers, pushes
//!   flush/compact/rebalance to background maintenance threads, and
//!   fans queries across pinned snapshot replicas through
//!   [`index::QueryRouter`]. One shared
//!   [`index::quantize::Quantizer`] keeps every float→cell map
//!   identical across all of them.
//! * [`cachesim`] — the cache-hierarchy simulator used to regenerate the
//!   paper's Figure 1(e) (LRU / set-associative / multi-level + TLB).
//! * [`runtime`] — the PJRT engine: loads AOT-compiled JAX/Pallas
//!   artifacts and executes them from the Rust hot path (compiled with
//!   the `pjrt` cargo feature; default builds use a dependency-free
//!   stub).
//! * [`util`] — deterministic RNG, a mini property-testing harness, the
//!   benchmark harness, and CLI plumbing.
//!
//! ## Quickstart
//!
//! ```
//! use sfc_mine::curves::engine::CurveMapper;
//! use sfc_mine::curves::CurveKind;
//!
//! // Every curve is an object-safe mapper (paper §2's C(i,j) ⇄ c):
//! let curve = CurveKind::Hilbert.mapper();
//! let c = curve.order(2, 3);
//! assert_eq!(curve.coords(c), (2, 3));
//!
//! // Batched conversion amortises automaton state across runs:
//! let mut orders = Vec::new();
//! curve.order_batch(&[(0, 0), (1, 0), (1, 1)], &mut orders);
//! assert_eq!(orders.len(), 3);
//!
//! // Arbitrary n×m rectangles traverse through the same interface
//! // (FUR overlay grid, §6.1), with a contiguous order-value range:
//! let rect = CurveKind::Hilbert.rect_mapper(3, 5);
//! let span = rect.domain().order_span().unwrap();
//! assert_eq!(rect.segments(0..span).count(), 15);
//!
//! // And the same abstraction in d dimensions (true d-dim Hilbert):
//! use sfc_mine::curves::engine::CurveMapperNd;
//! let cube = CurveKind::Hilbert.nd_mapper(3, 5); // 32×32×32
//! let mut p = [0u32; 3];
//! cube.coords_nd(cube.order_nd(&[7, 21, 30]), &mut p);
//! assert_eq!(p, [7, 21, 30]);
//! ```

#![warn(missing_docs)]

pub mod apps;
pub mod cachesim;
pub mod coordinator;
pub mod curves;
pub mod index;
pub mod linalg;
pub mod runtime;
pub mod util;

pub use curves::engine::{CurveMapper, CurveMapperNd};
pub use curves::nonrecursive::HilbertIter;
pub use curves::SpaceFillingCurve;

/// Library-wide error type.
///
/// Hand-rolled `Display`/`Error` impls (no `thiserror`): the crate is
/// dependency-free by design so it builds on the container's vendored
/// toolchain without a registry.
#[derive(Debug)]
pub enum Error {
    /// A grid/curve parameter was out of the supported domain.
    InvalidArgument(String),
    /// An artifact (AOT-compiled HLO module) was missing or malformed.
    Artifact(String),
    /// The PJRT runtime failed to compile or execute a module.
    Runtime(String),
    /// Numerical failure inside an application kernel (e.g. a non-PD matrix
    /// handed to Cholesky).
    Numerical(String),
    /// Coordinator/scheduling failure (worker panic, queue shutdown).
    Coordinator(String),
    /// An I/O failure.
    Io(std::io::Error),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::InvalidArgument(m) => write!(f, "invalid argument: {m}"),
            Error::Artifact(m) => write!(f, "artifact error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Numerical(m) => write!(f, "numerical error: {m}"),
            Error::Coordinator(m) => write!(f, "coordinator error: {m}"),
            Error::Io(e) => write!(f, "I/O error: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

/// Library-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_display_matches_legacy_format() {
        assert_eq!(
            Error::InvalidArgument("x".into()).to_string(),
            "invalid argument: x"
        );
        assert_eq!(Error::Artifact("y".into()).to_string(), "artifact error: y");
        assert_eq!(Error::Runtime("z".into()).to_string(), "runtime error: z");
        let io: Error = std::io::Error::new(std::io::ErrorKind::Other, "boom").into();
        assert!(io.to_string().starts_with("I/O error:"));
        assert!(std::error::Error::source(&io).is_some());
    }
}
