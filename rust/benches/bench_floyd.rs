//! §7 Floyd–Warshall bench: canonic vs tiled vs Hilbert-blocked inner
//! traversal.

use sfc_mine::apps::floyd::{
    floyd_canonic, floyd_hilbert_blocked, floyd_tiled, random_graph,
};
use sfc_mine::cachesim::{LruCache, MemSink};
use sfc_mine::curves::fur::general_hilbert_loop;
use sfc_mine::util::bench::Bench;
use sfc_mine::util::table::Table;

/// Replay the FW block-access trace through an LRU cache: block (bi, bj)
/// at pivot k touches d-blocks (bi,bj), (bi,bk), (bk,bj) — the paper's
/// miss metric at block granularity.
fn simulated_misses(nb: u32, block_bytes: u32, cache_blocks: u64, hilbert: bool) -> u64 {
    let mut cache = LruCache::with_bytes(cache_blocks * block_bytes as u64, block_bytes);
    for bk in 0..nb {
        let mut visit = |bi: u32, bj: u32| {
            for (i, j) in [(bi, bj), (bi, bk), (bk, bj)] {
                cache.touch((i as u64 * nb as u64 + j as u64) * block_bytes as u64, block_bytes);
            }
        };
        if hilbert {
            general_hilbert_loop(nb, nb, |bi, bj| visit(bi, bj));
        } else {
            for bi in 0..nb {
                for bj in 0..nb {
                    visit(bi, bj);
                }
            }
        }
    }
    cache.stats.misses
}

fn main() {
    let fast = std::env::var("SFC_BENCH_FAST").is_ok();
    let sizes: Vec<usize> = if fast { vec![96] } else { vec![256, 512] };
    let tile = 32usize;
    let mut bench = Bench::new();
    let mut table = Table::new(vec!["|V|", "variant", "median", "GUPS"]);

    for &n in &sizes {
        let g = random_graph(n, 0.05, 11);
        let updates = (n as f64).powi(3);
        let mut run = |name: &str, f: &dyn Fn() -> ()| {
            let m = bench.run(&format!("floyd/{name}/{n}"), f);
            table.row(vec![
                n.to_string(),
                name.to_string(),
                sfc_mine::util::bench::fmt_dur(m.median),
                format!("{:.3}", updates / m.median.as_secs_f64() / 1e9),
            ]);
        };
        run("canonic", &|| {
            let mut d = g.clone();
            floyd_canonic(&mut d);
        });
        run("tiled", &|| {
            let mut d = g.clone();
            floyd_tiled(&mut d, tile);
        });
        run("hilbert_blocked", &|| {
            let mut d = g.clone();
            floyd_hilbert_blocked(&mut d, tile);
        });
    }
    println!("\n== §7 Floyd–Warshall ==");
    print!("{}", table.render());

    let nb = 64u32;
    let block_bytes = 32 * 32 * 4u32;
    let mut miss_table = Table::new(vec!["LRU capacity (blocks)", "canonic", "hilbert", "ratio"]);
    for cache_blocks in [32u64, 64, 128, 256] {
        let mc = simulated_misses(nb, block_bytes, cache_blocks, false);
        let mh = simulated_misses(nb, block_bytes, cache_blocks, true);
        miss_table.row(vec![
            cache_blocks.to_string(),
            mc.to_string(),
            mh.to_string(),
            format!("{:.2}x", mc as f64 / mh as f64),
        ]);
    }
    println!("\n== simulated LRU block misses (2048² dist matrix as 64² blocks) ==");
    print!("{}", miss_table.render());
    miss_table.write_csv("reports/floyd_sim_misses.csv").unwrap();
    bench.write_csv("reports/bench_floyd.csv").unwrap();
}
