//! Asynchronous model updates (paper §7, after Plant & Böhm [21]):
//! MIMD k-Means where workers exchange intermediate results (centroids)
//! *without a barrier*, trading bounded staleness for zero idle time.
//!
//! Each worker sweeps its contiguous Hilbert-ordered point shard in
//! chunks; after every chunk it merges its partial (sums, counts) into the
//! shared model and refreshes its local centroid copy from the running
//! aggregate. The model therefore advances continuously within an epoch
//! instead of once per barrier — the paper's "frequency with which
//! processes exchange their intermediate results is optimized" idea, with
//! the chunk size as the exchange-frequency knob.

use crate::apps::kmeans::KMeans;
use crate::apps::Matrix;
use crate::coordinator::Coordinator;
use std::sync::Mutex;

/// Tuning for the asynchronous run.
#[derive(Copy, Clone, Debug)]
pub struct AsyncOpts {
    /// Points processed between model exchanges (the exchange frequency).
    pub sync_every: usize,
    /// Full sweeps over the data.
    pub epochs: usize,
}

impl Default for AsyncOpts {
    fn default() -> Self {
        AsyncOpts { sync_every: 1024, epochs: 8 }
    }
}

/// Shared running model: per-centroid coordinate sums and counts,
/// accumulated across all workers within an epoch.
struct SharedModel {
    sums: Vec<f64>,
    counts: Vec<u64>,
    centroids: Matrix,
}

impl SharedModel {
    fn snapshot_centroids(&self) -> Matrix {
        self.centroids.clone()
    }

    /// Merge a partial and refresh the centroid estimate from the running
    /// epoch aggregate (falling back to the previous position for
    /// still-empty clusters).
    fn merge(&mut self, part_sums: &[f64], part_counts: &[u64], d: usize) {
        for (a, b) in self.sums.iter_mut().zip(part_sums) {
            *a += b;
        }
        for (a, b) in self.counts.iter_mut().zip(part_counts) {
            *a += b;
        }
        for c in 0..self.counts.len() {
            if self.counts[c] > 0 {
                for idx in 0..d {
                    *self.centroids.at_mut(c, idx) =
                        (self.sums[c * d + idx] / self.counts[c] as f64) as f32;
                }
            }
        }
    }

    fn reset_epoch(&mut self) {
        self.sums.iter_mut().for_each(|s| *s = 0.0);
        self.counts.iter_mut().for_each(|c| *c = 0);
    }
}

/// Result of an asynchronous run.
#[derive(Clone, Debug)]
pub struct AsyncResult {
    /// Final centroids.
    pub centroids: Matrix,
    /// Inertia measured after each epoch (with the then-current model).
    pub inertia_trace: Vec<f64>,
    /// Total model exchanges performed.
    pub exchanges: u64,
}

/// Run asynchronous k-Means: workers sweep Hilbert-contiguous shards and
/// exchange partial models every `opts.sync_every` points, no barrier
/// inside an epoch.
pub fn async_kmeans(coord: &Coordinator, km: &KMeans, opts: AsyncOpts) -> AsyncResult {
    let n = km.points.rows;
    let k = km.centroids.rows;
    let d = km.points.cols;
    let shared = Mutex::new(SharedModel {
        sums: vec![0.0; k * d],
        counts: vec![0u64; k],
        centroids: km.centroids.clone(),
    });
    let exchanges = std::sync::atomic::AtomicU64::new(0);
    let mut inertia_trace = Vec::with_capacity(opts.epochs);

    for _epoch in 0..opts.epochs {
        shared.lock().unwrap().reset_epoch();
        coord.par_shards(n, |_id, start, end| {
            let mut local = shared.lock().unwrap().snapshot_centroids();
            let mut part_sums = vec![0.0f64; k * d];
            let mut part_counts = vec![0u64; k];
            let mut since_sync = 0usize;
            for p in start..end {
                let row = km.points.row(p);
                // Nearest centroid under the (possibly stale) local model.
                let (mut best_d, mut best_c) = (f32::INFINITY, 0usize);
                for c in 0..k {
                    let mut s = 0.0f32;
                    for (x, y) in row.iter().zip(local.row(c)) {
                        let t = x - y;
                        s += t * t;
                    }
                    if s < best_d {
                        best_d = s;
                        best_c = c;
                    }
                }
                for (idx, &x) in row.iter().enumerate() {
                    part_sums[best_c * d + idx] += x as f64;
                }
                part_counts[best_c] += 1;
                since_sync += 1;
                if since_sync >= opts.sync_every {
                    let mut m = shared.lock().unwrap();
                    m.merge(&part_sums, &part_counts, d);
                    local = m.snapshot_centroids();
                    drop(m);
                    part_sums.iter_mut().for_each(|s| *s = 0.0);
                    part_counts.iter_mut().for_each(|c| *c = 0);
                    since_sync = 0;
                    exchanges.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                }
            }
            // Tail merge.
            if part_counts.iter().any(|&c| c > 0) {
                shared.lock().unwrap().merge(&part_sums, &part_counts, d);
                exchanges.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            }
        });
        // Epoch diagnostics (not a barrier for correctness, only metrics).
        let model = shared.lock().unwrap().snapshot_centroids();
        let probe = KMeans { points: km.points.clone(), centroids: model };
        inertia_trace.push(crate::apps::kmeans::assign_naive(&probe).inertia());
    }

    AsyncResult {
        centroids: shared.into_inner().unwrap().centroids,
        inertia_trace,
        exchanges: exchanges.into_inner(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::kmeans::{init_centroids, lloyd, make_blobs, Assigner};

    fn problem(n: usize, k: usize, d: usize) -> KMeans {
        let (points, _) = make_blobs(n, k, d, 0.5, 31);
        let centroids = init_centroids(&points, k, 5);
        KMeans { points, centroids }
    }

    #[test]
    fn async_converges_close_to_sync() {
        let km = problem(800, 6, 4);
        // Sync reference.
        let mut sync_km = km.clone();
        let sync = lloyd(&mut sync_km, Assigner::Naive, 12, 1e-10);
        let sync_inertia = *sync.inertia_trace.last().unwrap();
        // Async with 3 workers.
        let coord = Coordinator::new(3);
        let res = async_kmeans(&coord, &km, AsyncOpts { sync_every: 64, epochs: 12 });
        let async_inertia = *res.inertia_trace.last().unwrap();
        assert!(
            async_inertia <= sync_inertia * 1.15,
            "async {async_inertia} vs sync {sync_inertia}"
        );
        assert!(res.exchanges > 0);
    }

    #[test]
    fn inertia_trend_is_downward() {
        let km = problem(600, 5, 3);
        let coord = Coordinator::new(2);
        let res = async_kmeans(&coord, &km, AsyncOpts { sync_every: 128, epochs: 8 });
        let first = res.inertia_trace[0];
        let last = *res.inertia_trace.last().unwrap();
        assert!(last <= first, "inertia {first} -> {last} must not worsen");
    }

    #[test]
    fn exchange_frequency_knob_counts() {
        let km = problem(500, 4, 3);
        let coord = Coordinator::new(2);
        let frequent = async_kmeans(&coord, &km, AsyncOpts { sync_every: 32, epochs: 2 });
        let rare = async_kmeans(&coord, &km, AsyncOpts { sync_every: 100_000, epochs: 2 });
        assert!(
            frequent.exchanges > rare.exchanges,
            "smaller sync_every must exchange more ({} vs {})",
            frequent.exchanges,
            rare.exchanges
        );
    }

    #[test]
    fn single_worker_single_epoch_is_one_lloyd_half_step() {
        // With one worker, sync_every >= n and one epoch, async k-means
        // degenerates to: assign all under initial model, then one merge.
        let km = problem(200, 3, 2);
        let coord = Coordinator::new(1);
        let res = async_kmeans(&coord, &km, AsyncOpts { sync_every: 1_000_000, epochs: 1 });
        let a = crate::apps::kmeans::assign_naive(&km);
        let expect = crate::apps::kmeans::update_centroids(&km, &a);
        assert!(res.centroids.max_abs_diff(&expect) < 1e-4);
    }
}
