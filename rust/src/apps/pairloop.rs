//! The Figure-1 experiment: an abstract algorithm that processes all pairs
//! `(i, j)` of two object collections, where processing a pair touches
//! object `i` of collection `B` and object `j` of collection `C` — the
//! paper's model of matrix multiplication, joins, and "countless algorithms
//! … formulated as two or three nested loops".
//!
//! Running the pair loop against a simulated cache of varying size, for the
//! canonic order versus the Hilbert order, regenerates Figure 1(e).

use crate::cachesim::trace::{AddressSpace, MemSink};
use crate::cachesim::LruCache;
use crate::curves::CurveKind;

/// Configuration of one pair-loop trace.
#[derive(Copy, Clone, Debug)]
pub struct PairLoopConfig {
    /// Objects in collection B (the `i` axis).
    pub n: u32,
    /// Objects in collection C (the `j` axis).
    pub m: u32,
    /// Object size in bytes (e.g. a matrix row: cols × 4).
    pub object_bytes: u32,
}

impl PairLoopConfig {
    /// Total bytes of both collections (the working set).
    pub fn working_set(&self) -> u64 {
        (self.n as u64 + self.m as u64) * self.object_bytes as u64
    }
}

/// Replay the pair loop in the given traversal order against `sink`.
///
/// Each pair `(i, j)` touches the whole of object `B_i` and object `C_j`
/// (the paper's scalar-product model reads both rows entirely).
pub fn trace_pairs<S: MemSink>(cfg: &PairLoopConfig, order: &[(u32, u32)], sink: &mut S) {
    let mut space = AddressSpace::new();
    let b_base = space.alloc((cfg.n as u64) * cfg.object_bytes as u64, 64);
    let c_base = space.alloc((cfg.m as u64) * cfg.object_bytes as u64, 64);
    for &(i, j) in order {
        debug_assert!(i < cfg.n && j < cfg.m);
        sink.touch(b_base + i as u64 * cfg.object_bytes as u64, cfg.object_bytes);
        sink.touch(c_base + j as u64 * cfg.object_bytes as u64, cfg.object_bytes);
    }
}

/// One Figure-1(e) data point: simulated LRU misses of a full pair loop.
pub fn misses_for(cfg: &PairLoopConfig, order: &[(u32, u32)], cache_bytes: u64, line: u32) -> u64 {
    let mut cache = LruCache::with_bytes(cache_bytes, line);
    trace_pairs(cfg, order, &mut cache);
    cache.stats.misses
}

/// A row of the Figure-1(e) sweep.
#[derive(Clone, Debug)]
pub struct Fig1Row {
    /// Cache size as a fraction of the working set.
    pub cache_fraction: f64,
    /// Cache size in bytes.
    pub cache_bytes: u64,
    /// Misses per traversal order, keyed like `orders`.
    pub misses: Vec<u64>,
}

/// Run the full Figure-1(e) sweep: LRU misses over varying cache size for
/// each traversal order. `fractions` are cache sizes as fractions of the
/// working set (the paper highlights 5–20%).
pub fn fig1e_sweep(
    cfg: &PairLoopConfig,
    orders: &[(CurveKind, Vec<(u32, u32)>)],
    fractions: &[f64],
    line: u32,
) -> Vec<Fig1Row> {
    let ws = cfg.working_set();
    fractions
        .iter()
        .map(|&f| {
            let cache_bytes = ((ws as f64 * f) as u64).max(line as u64);
            let misses = orders
                .iter()
                .map(|(_, order)| misses_for(cfg, order, cache_bytes, line))
                .collect();
            Fig1Row { cache_fraction: f, cache_bytes, misses }
        })
        .collect()
}

/// Compulsory (cold) miss floor: every distinct line of both collections
/// must be loaded at least once.
pub fn cold_misses(cfg: &PairLoopConfig, line: u32) -> u64 {
    let lines = |count: u64, bytes: u64| -> u64 { (count * bytes).div_ceil(line as u64) };
    lines(cfg.n as u64, cfg.object_bytes as u64) + lines(cfg.m as u64, cfg.object_bytes as u64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cachesim::CountingSink;
    use crate::curves::nonrecursive::HilbertIter;

    fn cfg() -> PairLoopConfig {
        PairLoopConfig { n: 32, m: 32, object_bytes: 64 }
    }

    fn canonic(n: u32, m: u32) -> Vec<(u32, u32)> {
        (0..n).flat_map(|i| (0..m).map(move |j| (i, j))).collect()
    }

    #[test]
    fn trace_touches_every_pair_twice() {
        let c = cfg();
        let order = canonic(c.n, c.m);
        let mut sink = CountingSink::default();
        trace_pairs(&c, &order, &mut sink);
        assert_eq!(sink.count, 2 * 32 * 32);
    }

    #[test]
    fn huge_cache_only_cold_misses() {
        let c = cfg();
        let order = canonic(c.n, c.m);
        let misses = misses_for(&c, &order, c.working_set() * 2, 64);
        assert_eq!(misses, cold_misses(&c, 64));
    }

    #[test]
    fn hilbert_beats_canonic_at_small_cache() {
        // The Figure-1(e) claim, in miniature: at cache sizes well below
        // the working set, the Hilbert traversal misses far less.
        let c = cfg();
        let canon = canonic(c.n, c.m);
        let hilb: Vec<_> = HilbertIter::new(32).collect();
        let cache = c.working_set() / 8; // 12.5% of working set
        let m_canon = misses_for(&c, &canon, cache, 64);
        let m_hilb = misses_for(&c, &hilb, cache, 64);
        assert!(
            m_hilb * 2 < m_canon,
            "hilbert {m_hilb} should be ≤ half of canonic {m_canon}"
        );
    }

    #[test]
    fn canonic_thrashes_below_working_set() {
        // LRU pathological case (§1): once C doesn't fit, every row of C
        // misses every outer iteration.
        let c = cfg();
        let canon = canonic(c.n, c.m);
        let cache = c.working_set() / 4;
        let misses = misses_for(&c, &canon, cache, 64);
        // n outer iterations × m rows of C ≈ full thrash on the C side.
        let thrash_floor = (c.n as u64) * (c.m as u64) / 2;
        assert!(misses > thrash_floor, "misses {misses} < floor {thrash_floor}");
    }

    #[test]
    fn sweep_is_monotone_for_canonic() {
        let c = cfg();
        let orders = vec![(CurveKind::Canonic, canonic(c.n, c.m))];
        let rows = fig1e_sweep(&c, &orders, &[0.05, 0.2, 0.5, 1.5], 64);
        for w in rows.windows(2) {
            assert!(
                w[0].misses[0] >= w[1].misses[0],
                "more cache must not increase LRU misses on this trace"
            );
        }
    }

    #[test]
    fn working_set_math() {
        let c = cfg();
        assert_eq!(c.working_set(), (32 + 32) * 64);
    }
}
