//! Fixed-bucket log2 latency histogram (ISSUE 10).
//!
//! Every latency surface in the crate — the store CLI's mixed-workload
//! phase, `bench_store`'s query rows, and all serving-pipeline metrics
//! ([`crate::index::store::pipeline`]) — records nanosecond samples
//! into a [`LatencyHistogram`] instead of keeping a sorted `Vec` of
//! raw samples. The histogram is a fixed 976-counter array (constant
//! memory no matter how many samples land in it, no sort at read
//! time), mergeable across threads, with ≤ 1/16 ≈ 6.25% relative
//! quantile error by construction.
//!
//! ## Bucketing
//!
//! HdrHistogram-style log-linear buckets: values below 16 ns map to
//! exact unit buckets; every higher octave `[2^o, 2^(o+1))` splits
//! into 16 linear sub-buckets of width `2^(o-4)`. The bucket index of
//! a value `v` with highest set bit `o ≥ 4` is
//!
//! ```text
//! idx = (o - 3) * 16 + ((v >> (o - 4)) & 15)
//! ```
//!
//! which is continuous with the unit region (`v = 16` lands in bucket
//! 16) and covers the whole `u64` range in `(64 - 3) * 16 = 976`
//! buckets. Quantiles walk the counters and report the **upper edge**
//! of the bucket holding the target rank, so a reported p99 is never
//! below the true p99 and at most one sub-bucket width above it.

/// Number of linear sub-buckets per octave (and the size of the exact
/// unit region).
const SUB: usize = 16;
/// log2(SUB).
const SUB_BITS: u32 = 4;
/// Total bucket count: unit region + 60 sub-divided octaves.
const BUCKETS: usize = (64 - SUB_BITS as usize + 1) * SUB;

/// Bucket index of `v` (see the module docs for the layout).
#[inline]
fn bucket_of(v: u64) -> usize {
    if v < SUB as u64 {
        v as usize
    } else {
        let o = 63 - v.leading_zeros();
        ((o - SUB_BITS + 1) as usize) * SUB + ((v >> (o - SUB_BITS)) as usize & (SUB - 1))
    }
}

/// Inclusive upper edge of bucket `idx` — the value quantiles report.
#[inline]
fn bucket_hi(idx: usize) -> u64 {
    if idx < SUB {
        idx as u64
    } else {
        let o = (idx / SUB) as u32 + SUB_BITS - 1;
        let sub = (idx % SUB) as u64;
        let width = 1u64 << (o - SUB_BITS);
        (sub << (o - SUB_BITS)) + (1u64 << o) + width - 1
    }
}

/// A mergeable fixed-memory log2 histogram of nanosecond latencies.
///
/// Typical use: one histogram per worker thread, `merge`d into one at
/// report time, then `p50()`/`p99()`/`p999()`/`max_ns()`.
#[derive(Clone)]
pub struct LatencyHistogram {
    counts: Box<[u64; BUCKETS]>,
    count: u64,
    sum: u64,
    max: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    /// An empty histogram.
    pub fn new() -> Self {
        LatencyHistogram { counts: Box::new([0u64; BUCKETS]), count: 0, sum: 0, max: 0 }
    }

    /// Record one sample in nanoseconds.
    #[inline]
    pub fn record(&mut self, ns: u64) {
        self.counts[bucket_of(ns)] += 1;
        self.count += 1;
        self.sum = self.sum.saturating_add(ns);
        self.max = self.max.max(ns);
    }

    /// Record an elapsed [`std::time::Duration`].
    #[inline]
    pub fn record_duration(&mut self, d: std::time::Duration) {
        self.record(d.as_nanos().min(u64::MAX as u128) as u64);
    }

    /// Fold another histogram into this one (exact: bucket-wise sums).
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum = self.sum.saturating_add(other.sum);
        self.max = self.max.max(other.max);
    }

    /// Number of recorded samples.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Exact maximum recorded sample (not bucketed).
    pub fn max_ns(&self) -> u64 {
        self.max
    }

    /// Mean of all samples in nanoseconds (0 when empty).
    pub fn mean_ns(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.sum / self.count
        }
    }

    /// The `q`-quantile (`0 < q ≤ 1`) in nanoseconds: the upper edge of
    /// the bucket holding the sample of rank `ceil(q · count)`. Returns
    /// 0 on an empty histogram; `quantile(1.0)` returns the exact max.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        if q >= 1.0 {
            return self.max;
        }
        let rank = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for (idx, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                // Never report past the true max (the last occupied
                // bucket's edge can exceed it).
                return bucket_hi(idx).min(self.max);
            }
        }
        self.max
    }

    /// Median.
    pub fn p50(&self) -> u64 {
        self.quantile(0.50)
    }

    /// 99th percentile.
    pub fn p99(&self) -> u64 {
        self.quantile(0.99)
    }

    /// 99.9th percentile.
    pub fn p999(&self) -> u64 {
        self.quantile(0.999)
    }

    /// `"p50 12.3µs p99 45.6µs p999 1.2ms max 3.4ms"` — the one-line
    /// form every CLI/bench surface prints.
    pub fn summary(&self) -> String {
        format!(
            "p50 {} p99 {} p999 {} max {}",
            fmt_ns(self.p50()),
            fmt_ns(self.p99()),
            fmt_ns(self.p999()),
            fmt_ns(self.max)
        )
    }
}

/// Human-readable nanoseconds (`ns`/`µs`/`ms`/`s`).
pub fn fmt_ns(ns: u64) -> String {
    if ns < 1_000 {
        format!("{ns}ns")
    } else if ns < 1_000_000 {
        format!("{:.1}µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2}ms", ns as f64 / 1e6)
    } else {
        format!("{:.2}s", ns as f64 / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unit_region_is_exact() {
        let mut h = LatencyHistogram::new();
        for v in 0..16u64 {
            h.record(v);
        }
        assert_eq!(h.count(), 16);
        assert_eq!(h.quantile(1.0 / 16.0), 0);
        assert_eq!(h.p50(), 7);
        assert_eq!(h.max_ns(), 15);
    }

    #[test]
    fn buckets_are_monotone_and_cover_u64() {
        let mut prev = 0usize;
        let mut v = 1u64;
        while v < u64::MAX / 2 {
            let b = bucket_of(v);
            assert!(b >= prev, "bucket index must be monotone at v={v}");
            assert!(b < BUCKETS);
            assert!(bucket_hi(b) >= v, "upper edge must bound the value at v={v}");
            prev = b;
            v = v.wrapping_mul(3) + 1;
        }
        assert!(bucket_of(u64::MAX) < BUCKETS);
    }

    #[test]
    fn quantiles_match_sorted_vec_within_bound() {
        // Deterministic LCG workload spanning ns..ms scales.
        let mut x = 0x2545f4914f6cdd1du64;
        let mut samples: Vec<u64> = (0..10_000)
            .map(|_| {
                x ^= x << 13;
                x ^= x >> 7;
                x ^= x << 17;
                x % 5_000_000
            })
            .collect();
        let mut h = LatencyHistogram::new();
        for &s in &samples {
            h.record(s);
        }
        samples.sort_unstable();
        for q in [0.5, 0.9, 0.99, 0.999] {
            let rank = ((q * samples.len() as f64).ceil() as usize).clamp(1, samples.len());
            let exact = samples[rank - 1];
            let got = h.quantile(q);
            // Upper-edge reporting: got >= exact, within one sub-bucket
            // (6.25% relative + the unit region floor).
            assert!(got >= exact, "q={q}: got {got} < exact {exact}");
            assert!(
                got as f64 <= exact as f64 * (1.0 + 1.0 / SUB as f64) + 1.0,
                "q={q}: got {got} too far above exact {exact}"
            );
        }
        assert_eq!(h.quantile(1.0), *samples.last().unwrap());
    }

    #[test]
    fn merge_equals_combined_recording() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        let mut all = LatencyHistogram::new();
        for i in 0..1000u64 {
            let v = i * 977 % 100_000;
            if i % 2 == 0 {
                a.record(v);
            } else {
                b.record(v);
            }
            all.record(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        assert_eq!(a.max_ns(), all.max_ns());
        assert_eq!(a.mean_ns(), all.mean_ns());
        for q in [0.5, 0.9, 0.99, 0.999, 1.0] {
            assert_eq!(a.quantile(q), all.quantile(q), "q={q}");
        }
    }

    #[test]
    fn empty_histogram_reports_zero() {
        let h = LatencyHistogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.p50(), 0);
        assert_eq!(h.p999(), 0);
        assert_eq!(h.mean_ns(), 0);
    }
}
