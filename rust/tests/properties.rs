//! Repo-wide property tests (the proptest-role suite; see DESIGN.md §3).
//!
//! Each property sweeps a seeded, size-ramped input space via
//! `util::check::forall` and shrinks failures to minimal counterexamples.

use sfc_mine::apps::simjoin::{join_bruteforce, join_grid_nested, make_clustered, normalize};
use sfc_mine::cachesim::LruCache;
use sfc_mine::curves::fgf::{fgf_path, BlockClass, Rect, Region, UpperTriangle};
use sfc_mine::curves::fur::{general_hilbert_path, FurHilbert};
use sfc_mine::curves::gray::GrayCode;
use sfc_mine::curves::hilbert::Hilbert;
use sfc_mine::curves::lindenmayer::hilbert_path;
use sfc_mine::curves::nonrecursive::HilbertIter;
use sfc_mine::curves::peano::Peano;
use sfc_mine::curves::zorder::ZOrder;
use sfc_mine::curves::SpaceFillingCurve;
use sfc_mine::util::check::{forall, forall_seeded};
use sfc_mine::util::rng::Rng;
use std::collections::HashSet;

// --------------------------------------------------------------------------
// Curve bijectivity on the full u32 domain
// --------------------------------------------------------------------------

#[test]
fn prop_all_curves_roundtrip_any_coords() {
    forall::<(u32, u32)>("roundtrip-hilbert", |&(i, j)| {
        Hilbert::coords(Hilbert::order(i, j)) == (i, j)
    });
    forall::<(u32, u32)>("roundtrip-zorder", |&(i, j)| {
        ZOrder::coords(ZOrder::order(i, j)) == (i, j)
    });
    forall::<(u32, u32)>("roundtrip-gray", |&(i, j)| {
        GrayCode::coords(GrayCode::order(i, j)) == (i, j)
    });
    forall::<(u32, u32)>("roundtrip-peano", |&(i, j)| {
        Peano::coords(Peano::order(i, j)) == (i, j)
    });
}

#[test]
fn prop_curves_injective_on_random_pairs() {
    // Distinct coordinate pairs map to distinct order values.
    forall::<(u32, u32)>("injective", |&(a, b)| {
        let p1 = (a & 0xFFFF, b & 0xFFFF);
        let p2 = (b & 0xFFFF, a & 0xFFFF);
        if p1 == p2 {
            return true;
        }
        Hilbert::order(p1.0, p1.1) != Hilbert::order(p2.0, p2.1)
            && ZOrder::order(p1.0, p1.1) != ZOrder::order(p2.0, p2.1)
            && Peano::order(p1.0, p1.1) != Peano::order(p2.0, p2.1)
    });
}

// --------------------------------------------------------------------------
// Generator equivalence: Mealy ≡ Lindenmayer ≡ Figure-5 ≡ range-resume
// --------------------------------------------------------------------------

#[test]
fn prop_hilbert_generators_equivalent() {
    for level in 0..=7u32 {
        let rec = hilbert_path(level);
        let nonrec: Vec<_> = HilbertIter::with_level(level).collect();
        assert_eq!(rec, nonrec, "L={level}");
        // Spot-check Mealy equality at random order values.
        let mut rng = Rng::new(level as u64);
        for _ in 0..50 {
            let h = rng.below(1u64 << (2 * level));
            assert_eq!(rec[h as usize], Hilbert::coords_at_level(h, level));
        }
    }
}

#[test]
fn prop_range_resume_equals_full_iteration() {
    forall_seeded::<(u32, u32)>("range-resume", 99, 128, |&(a, b)| {
        let level = 6u32;
        let total = 1u64 << (2 * level);
        let s = (a as u64) % total;
        let len = (b as u64) % 200;
        let e = (s + len).min(total);
        let expect: Vec<_> = HilbertIter::with_level(level)
            .skip(s as usize)
            .take((e - s) as usize)
            .collect();
        let got: Vec<_> = HilbertIter::range(level, s, e).collect();
        expect == got
    });
}

// --------------------------------------------------------------------------
// FUR / generalized curves over random rectangles
// --------------------------------------------------------------------------

#[test]
fn prop_fur_is_permutation_any_rectangle() {
    forall_seeded::<(u32, u32)>("fur-permutation", 7, 160, |&(n, m)| {
        let (n, m) = (n % 200 + 1, m % 200 + 1);
        let p = FurHilbert::path(n, m);
        if p.len() != (n as usize) * (m as usize) {
            return false;
        }
        let set: HashSet<_> = p.iter().copied().collect();
        set.len() == p.len() && p.iter().all(|&(i, j)| i < n && j < m)
    });
}

#[test]
fn prop_general_hilbert_near_unit_steps() {
    forall_seeded::<(u32, u32)>("gilbert-steps", 13, 160, |&(n, m)| {
        let (n, m) = (n % 150 + 1, m % 150 + 1);
        let p = general_hilbert_path(n, m);
        let non_unit = p
            .windows(2)
            .map(|w| {
                (w[1].0 as i64 - w[0].0 as i64).abs() + (w[1].1 as i64 - w[0].1 as i64).abs()
            })
            .filter(|&d| d != 1)
            .count();
        non_unit <= 1
    });
}

// --------------------------------------------------------------------------
// FGF invariants over random regions
// --------------------------------------------------------------------------

#[test]
fn prop_fgf_accounts_every_order_value() {
    forall_seeded::<(u32, u32)>("fgf-accounting", 23, 96, |&(n, m)| {
        let level = 6u32;
        let side = 1u32 << level;
        let r = Rect { n: n % (side + 20), m: m % (side + 20) };
        let (_, stats) = fgf_path(level, &r);
        stats.visited + stats.skipped == 1u64 << (2 * level)
    });
}

#[test]
fn prop_fgf_visits_exactly_region_cells() {
    forall_seeded::<(u32, u32)>("fgf-membership", 29, 64, |&(n, m)| {
        let level = 5u32;
        let side = 1u32 << level;
        let r = Rect { n: n % side + 1, m: m % side + 1 };
        let (path, _) = fgf_path(level, &r);
        let brute: usize = (r.n.min(side) as usize) * (r.m.min(side) as usize);
        path.len() == brute && path.iter().all(|&(i, j, _)| i < r.n && j < r.m)
    });
}

#[test]
fn prop_fgf_hilbert_values_strictly_increase() {
    let (path, _) = fgf_path(7, &UpperTriangle);
    assert!(path.windows(2).all(|w| w[0].2 < w[1].2));
    // And each equals the true Mealy value.
    let mut rng = Rng::new(5);
    for _ in 0..200 {
        let idx = rng.below_usize(path.len());
        let (i, j, h) = path[idx];
        assert_eq!(Hilbert::order_at_level(i, j, 7), h);
    }
}

#[test]
fn prop_region_classify_consistent_with_membership() {
    // A region's block classification must agree with cell membership.
    forall_seeded::<(u32, u32, u32)>("region-consistency", 31, 96, |&(i0, j0, lv)| {
        let level = lv % 4;
        let (i0, j0) = (i0 % 64, j0 % 64);
        let r = UpperTriangle;
        let s = 1u32 << level;
        let class = r.classify(i0, j0, level);
        let mut any = false;
        let mut all = true;
        for i in i0..i0 + s {
            for j in j0..j0 + s {
                if i < j {
                    any = true;
                } else {
                    all = false;
                }
            }
        }
        match class {
            BlockClass::Full => all,
            BlockClass::Disjoint => !any,
            BlockClass::Partial => true,
        }
    });
}

// --------------------------------------------------------------------------
// Cache simulator: LRU inclusion property
// --------------------------------------------------------------------------

#[test]
fn prop_lru_inclusion_bigger_cache_never_worse() {
    // Fully-associative LRU has the stack property: misses are monotone
    // non-increasing in capacity, for ANY trace.
    forall_seeded::<u64>("lru-inclusion", 41, 48, |&seed| {
        let mut rng = Rng::new(seed);
        let trace: Vec<u64> = (0..800).map(|_| rng.below(120)).collect();
        let mut last = u64::MAX;
        for cap in [4usize, 8, 16, 32, 64, 128] {
            let mut c = LruCache::new(cap, 64);
            for &t in &trace {
                c.access_tag(t);
            }
            if c.stats.misses > last {
                return false;
            }
            last = c.stats.misses;
        }
        true
    });
}

// --------------------------------------------------------------------------
// Similarity join: result-set equality on random workloads
// --------------------------------------------------------------------------

#[test]
fn prop_simjoin_variants_agree() {
    forall_seeded::<(u32, u32)>("simjoin-agree", 43, 12, |&(a, b)| {
        let n = (a % 150 + 20) as usize;
        let eps = 0.3 + (b % 20) as f32 * 0.1;
        let points = make_clustered(n, 3, 5, 0.6, a as u64 * 7 + 1);
        let (x, _) = join_bruteforce(&points, eps);
        let (y, _) = join_grid_nested(&points, eps);
        let (z, _) = sfc_mine::apps::simjoin::join_fgf_hilbert(&points, eps);
        let x = normalize(x);
        x == normalize(y) && x == normalize(z)
    });
}

// --------------------------------------------------------------------------
// Hilbert locality bound (a paper-level guarantee)
// --------------------------------------------------------------------------

#[test]
fn prop_hilbert_consecutive_values_are_neighbors() {
    forall::<u64>("hilbert-adjacency", |&h| {
        let h = h & ((1u64 << 32) - 2); // keep h+1 in range
        let (i1, j1) = Hilbert::coords(h);
        let (i2, j2) = Hilbert::coords(h + 1);
        let d = (i1 as i64 - i2 as i64).abs() + (j1 as i64 - j2 as i64).abs();
        d == 1
    });
}
