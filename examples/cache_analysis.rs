//! Regenerate the paper's Figure 1 from the cache simulator.
//!
//! Writes CSV series to `reports/`:
//!   fig1a_canonic.csv / fig1b_hilbert.csv — traversal orders (8×8)
//!   fig1cd_histories.csv                  — i(t), j(t) for both orders
//!   fig1e_misses.csv                      — LRU misses vs cache size
//!
//! ```sh
//! cargo run --release --example cache_analysis
//! ```

use sfc_mine::apps::pairloop::{fig1e_sweep, PairLoopConfig};
use sfc_mine::curves::nonrecursive::HilbertIter;
use sfc_mine::curves::{metrics, CurveKind};
use sfc_mine::util::table::Table;

fn main() -> std::io::Result<()> {
    std::fs::create_dir_all("reports")?;

    // --- Fig 1(a)/(b): traversal orders on 8x8 ---------------------------
    for (name, path) in [
        ("fig1a_canonic", CurveKind::Canonic.enumerate(8)),
        ("fig1b_hilbert", HilbertIter::new(8).collect::<Vec<_>>()),
    ] {
        let mut t = Table::new(vec!["t", "i", "j"]);
        for (step, (i, j)) in path.iter().enumerate() {
            t.row(vec![step.to_string(), i.to_string(), j.to_string()]);
        }
        t.write_csv(&format!("reports/{name}.csv"))?;
        println!("wrote reports/{name}.csv ({} rows)", t.len());
    }

    // --- Fig 1(c)/(d): i/j histories on 64x64 -----------------------------
    let n = 64u32;
    let canonic = CurveKind::Canonic.enumerate(n);
    let hilbert: Vec<_> = HilbertIter::new(n).collect();
    let (ci, cj) = metrics::histories(&canonic);
    let (hi, hj) = metrics::histories(&hilbert);
    let mut t = Table::new(vec!["t", "canonic_i", "canonic_j", "hilbert_i", "hilbert_j"]);
    for step in 0..canonic.len() {
        t.row(vec![
            step.to_string(),
            ci[step].to_string(),
            cj[step].to_string(),
            hi[step].to_string(),
            hj[step].to_string(),
        ]);
    }
    t.write_csv("reports/fig1cd_histories.csv")?;
    println!("wrote reports/fig1cd_histories.csv ({} rows)", t.len());

    // --- Fig 1(e): LRU misses vs cache size --------------------------------
    // 256 objects per side, 256-byte objects (a 64-float matrix row).
    let cfg = PairLoopConfig { n: 256, m: 256, object_bytes: 256 };
    let orders: Vec<(CurveKind, Vec<(u32, u32)>)> = vec![
        (CurveKind::Canonic, CurveKind::Canonic.enumerate(256)),
        (CurveKind::ZOrder, CurveKind::ZOrder.enumerate(256)),
        (CurveKind::Hilbert, HilbertIter::new(256).collect()),
    ];
    let fractions: Vec<f64> = (1..=50).map(|p| p as f64 / 100.0).collect();
    let rows = fig1e_sweep(&cfg, &orders, &fractions, 64);

    let mut t = Table::new(vec!["cache_frac", "cache_bytes", "canonic", "zorder", "hilbert"]);
    for r in &rows {
        t.row(vec![
            format!("{:.2}", r.cache_fraction),
            r.cache_bytes.to_string(),
            r.misses[0].to_string(),
            r.misses[1].to_string(),
            r.misses[2].to_string(),
        ]);
    }
    t.write_csv("reports/fig1e_misses.csv")?;
    println!("wrote reports/fig1e_misses.csv");

    // Print the headline slice (the paper highlights 5-20% cache sizes).
    println!("\nFig 1(e) — LRU misses (working set {} KiB):", cfg.working_set() / 1024);
    let mut headline = Table::new(vec!["cache %", "canonic", "zorder", "hilbert", "canonic/hilbert"]);
    for r in rows.iter().filter(|r| {
        [0.05, 0.10, 0.15, 0.20, 0.30, 0.50].iter().any(|f| (r.cache_fraction - f).abs() < 1e-9)
    }) {
        headline.row(vec![
            format!("{:.0}%", r.cache_fraction * 100.0),
            r.misses[0].to_string(),
            r.misses[1].to_string(),
            r.misses[2].to_string(),
            format!("{:.1}x", r.misses[0] as f64 / r.misses[2] as f64),
        ]);
    }
    print!("{}", headline.render());
    Ok(())
}
