//! Locality analysis for traversal orders (paper Fig 1(c)/(d) and the
//! qualitative comparisons of §2).

use std::collections::HashMap;

/// Summary statistics of the step lengths of a traversal.
#[derive(Clone, Debug, PartialEq)]
pub struct StepStats {
    /// Mean Manhattan step length (1.0 for a perfect space-filling curve).
    pub avg: f64,
    /// Maximum step length.
    pub max: u64,
    /// Histogram: step length → count.
    pub histogram: HashMap<u64, u64>,
    /// Number of steps (|path| − 1).
    pub steps: u64,
}

/// Compute step statistics of a traversal path.
pub fn step_stats(path: &[(u32, u32)]) -> StepStats {
    let mut histogram = HashMap::new();
    let mut total = 0u64;
    let mut max = 0u64;
    for w in path.windows(2) {
        let d = (w[1].0 as i64 - w[0].0 as i64).unsigned_abs()
            + (w[1].1 as i64 - w[0].1 as i64).unsigned_abs();
        *histogram.entry(d).or_insert(0) += 1;
        total += d;
        max = max.max(d);
    }
    let steps = path.len().saturating_sub(1) as u64;
    StepStats {
        avg: if steps == 0 { 0.0 } else { total as f64 / steps as f64 },
        max,
        histogram,
        steps,
    }
}

/// The i/j histories over time (paper Fig 1(c),(d)): the sequences
/// `i(t)` and `j(t)` of a traversal.
pub fn histories(path: &[(u32, u32)]) -> (Vec<u32>, Vec<u32>) {
    (
        path.iter().map(|&(i, _)| i).collect(),
        path.iter().map(|&(_, j)| j).collect(),
    )
}

/// Working-set profile: number of *distinct* values of one coordinate within
/// a sliding window of `w` consecutive loop iterations — a direct proxy for
/// how many distinct cache-resident objects the traversal touches. Returns
/// the mean over all window positions.
///
/// For the canonic order the `j` working set of a window spanning whole rows
/// is the entire axis; for the Hilbert order it stays near `√w`.
pub fn mean_window_working_set(history: &[u32], w: usize) -> f64 {
    if history.len() < w || w == 0 {
        return history
            .iter()
            .collect::<std::collections::HashSet<_>>()
            .len() as f64;
    }
    // Sliding multiset with distinct counter.
    let mut counts: HashMap<u32, u32> = HashMap::new();
    let mut distinct = 0u64;
    let mut sum = 0u64;
    let mut windows = 0u64;
    for (t, &v) in history.iter().enumerate() {
        let e = counts.entry(v).or_insert(0);
        if *e == 0 {
            distinct += 1;
        }
        *e += 1;
        if t + 1 >= w {
            sum += distinct;
            windows += 1;
            let old = history[t + 1 - w];
            let e = counts.get_mut(&old).unwrap();
            *e -= 1;
            if *e == 0 {
                distinct -= 1;
            }
        }
    }
    sum as f64 / windows as f64
}

/// Average over both coordinates of [`mean_window_working_set`] — the
/// single-number locality score used in reports (lower = more local).
pub fn locality_score(path: &[(u32, u32)], window: usize) -> f64 {
    let (hi, hj) = histories(path);
    (mean_window_working_set(&hi, window) + mean_window_working_set(&hj, window)) / 2.0
}

/// Step statistics of a **d-dimensional** traversal path, given as the
/// flattened coordinate buffer produced by
/// [`engine::collect_nd`](crate::curves::engine::collect_nd) (`dims`
/// entries per point). Manhattan step length over all axes; 1.0 average
/// for a perfect space-filling curve in any dimension.
pub fn step_stats_nd(path: &[u32], dims: usize) -> StepStats {
    assert!(dims >= 1, "dims must be ≥ 1");
    assert_eq!(path.len() % dims, 0, "path length must be a multiple of dims");
    let points = path.len() / dims;
    let mut histogram = HashMap::new();
    let mut total = 0u64;
    let mut max = 0u64;
    for t in 1..points {
        let prev = &path[(t - 1) * dims..t * dims];
        let cur = &path[t * dims..(t + 1) * dims];
        let d: u64 = prev
            .iter()
            .zip(cur)
            .map(|(&x, &y)| (y as i64 - x as i64).unsigned_abs())
            .sum();
        *histogram.entry(d).or_insert(0) += 1;
        total += d;
        max = max.max(d);
    }
    let steps = points.saturating_sub(1) as u64;
    StepStats {
        avg: if steps == 0 { 0.0 } else { total as f64 / steps as f64 },
        max,
        histogram,
        steps,
    }
}

/// Per-axis coordinate history of a flattened d-dimensional path — the
/// Nd counterpart of [`histories`].
pub fn history_axis(path: &[u32], dims: usize, axis: usize) -> Vec<u32> {
    assert!(axis < dims);
    path.iter().skip(axis).step_by(dims).copied().collect()
}

/// Average over all axes of [`mean_window_working_set`] — the
/// single-number locality score for d-dimensional traversals.
pub fn locality_score_nd(path: &[u32], dims: usize, window: usize) -> f64 {
    assert!(dims >= 1, "dims must be ≥ 1");
    assert_eq!(path.len() % dims, 0, "path length must be a multiple of dims");
    let mut acc = 0.0;
    for axis in 0..dims {
        acc += mean_window_working_set(&history_axis(path, dims, axis), window);
    }
    acc / dims as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::curves::nonrecursive::HilbertIter;
    use crate::curves::CurveKind;

    #[test]
    fn unit_path_stats() {
        let path = [(0u32, 0u32), (0, 1), (1, 1), (1, 0)];
        let s = step_stats(&path);
        assert_eq!(s.avg, 1.0);
        assert_eq!(s.max, 1);
        assert_eq!(s.steps, 3);
        assert_eq!(s.histogram[&1], 3);
    }

    #[test]
    fn canonic_has_row_jumps() {
        let path = CurveKind::Canonic.enumerate(8);
        let s = step_stats(&path);
        assert_eq!(s.max, 8, "wrap from (i,7) to (i+1,0) costs 1+7");
        assert!(s.avg > 1.0);
    }

    #[test]
    fn hilbert_is_unit_step() {
        let path: Vec<_> = HilbertIter::new(16).collect();
        let s = step_stats(&path);
        assert_eq!(s.avg, 1.0);
        assert_eq!(s.max, 1);
    }

    #[test]
    fn zorder_has_large_jumps() {
        let path = CurveKind::ZOrder.enumerate(16);
        let s = step_stats(&path);
        assert!(s.max > 8, "Z-order's diagonal jumps, got max={}", s.max);
    }

    #[test]
    fn histories_shapes() {
        let path = [(0u32, 0u32), (1, 0), (1, 1)];
        let (hi, hj) = histories(&path);
        assert_eq!(hi, vec![0, 1, 1]);
        assert_eq!(hj, vec![0, 0, 1]);
    }

    #[test]
    fn working_set_canonic_vs_hilbert() {
        // Fig 1(c,d) quantified: over a window of n iterations, canonic
        // touches n distinct j values but only 1 distinct i; Hilbert stays
        // near √n on both.
        let n = 32u32;
        let canonic = CurveKind::Canonic.enumerate(n);
        let hilbert: Vec<_> = HilbertIter::new(n).collect();
        let w = n as usize;
        let (_, cj) = histories(&canonic);
        let (_, hj) = histories(&hilbert);
        let canonic_ws = mean_window_working_set(&cj, w);
        let hilbert_ws = mean_window_working_set(&hj, w);
        assert!(canonic_ws > (n - 1) as f64, "canonic j-ws ≈ n, got {canonic_ws}");
        assert!(
            hilbert_ws < canonic_ws / 2.0,
            "hilbert j-ws {hilbert_ws} should be far below canonic {canonic_ws}"
        );
    }

    #[test]
    fn locality_score_orders_curves() {
        let n = 32u32;
        let hilbert: Vec<_> = HilbertIter::new(n).collect();
        let canonic = CurveKind::Canonic.enumerate(n);
        let w = 64;
        assert!(locality_score(&hilbert, w) < locality_score(&canonic, w));
    }

    #[test]
    fn window_bigger_than_path() {
        let path = [(0u32, 0u32), (0, 1)];
        let (hi, _) = histories(&path);
        // Falls back to global distinct count.
        assert_eq!(mean_window_working_set(&hi, 10), 1.0);
    }

    #[test]
    fn step_stats_nd_matches_2d_on_pairs() {
        let pairs = CurveKind::ZOrder.enumerate(8);
        let flat: Vec<u32> = pairs.iter().flat_map(|&(i, j)| [i, j]).collect();
        let s2 = step_stats(&pairs);
        let sn = step_stats_nd(&flat, 2);
        assert_eq!(s2.avg, sn.avg);
        assert_eq!(s2.max, sn.max);
        assert_eq!(s2.steps, sn.steps);
        assert_eq!(s2.histogram, sn.histogram);
    }

    #[test]
    fn hilbert_nd_average_step_is_unit() {
        use crate::curves::engine::collect_nd;
        use crate::curves::ndim::HilbertNd;
        for dims in [2usize, 3, 4] {
            let m = HilbertNd::new(dims, 3);
            let path = collect_nd(&m);
            let s = step_stats_nd(&path, dims);
            assert_eq!(s.avg, 1.0, "d={dims}");
            assert_eq!(s.max, 1, "d={dims}");
        }
    }

    #[test]
    fn locality_score_nd_orders_curves_in_3d() {
        use crate::curves::engine::collect_nd;
        let h = CurveKind::Hilbert.nd_mapper(3, 3);
        let c = CurveKind::Canonic.nd_mapper(3, 3);
        let hp = collect_nd(h.as_ref());
        let cp = collect_nd(c.as_ref());
        assert!(locality_score_nd(&hp, 3, 64) < locality_score_nd(&cp, 3, 64));
    }
}
