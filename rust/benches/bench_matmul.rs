//! §7 matrix multiplication bench: canonic vs transposed vs tiled
//! (cache-conscious) vs Hilbert (cache-oblivious), wallclock + GFLOP/s,
//! plus a block-size ablation for the Hilbert variant.

use sfc_mine::apps::matmul::{
    flops, matmul_hilbert, matmul_naive, matmul_tiled, matmul_transposed,
};
use sfc_mine::apps::Matrix;
use sfc_mine::util::bench::Bench;
use sfc_mine::util::table::Table;

fn main() {
    let fast = std::env::var("SFC_BENCH_FAST").is_ok();
    let sizes: Vec<usize> = if fast { vec![128] } else { vec![256, 512, 1024] };
    let tile = 32usize;
    let mut bench = Bench::new();
    let mut table = Table::new(vec!["n", "variant", "median", "GFLOP/s"]);

    for &n in &sizes {
        let b = Matrix::random(n, n, 1, -1.0, 1.0);
        let c = Matrix::random(n, n, 2, -1.0, 1.0);
        let fl = flops(n, n, n);
        let mut run = |name: &str, f: &dyn Fn() -> Matrix| {
            let m = bench.run(&format!("matmul/{name}/{n}"), f);
            table.row(vec![
                n.to_string(),
                name.to_string(),
                sfc_mine::util::bench::fmt_dur(m.median),
                format!("{:.2}", fl as f64 / m.median.as_secs_f64() / 1e9),
            ]);
        };
        if n <= 256 {
            run("naive", &|| matmul_naive(&b, &c));
        }
        run("transposed", &|| matmul_transposed(&b, &c));
        run("tiled", &|| matmul_tiled(&b, &c, tile));
        run("hilbert", &|| matmul_hilbert(&b, &c, tile));
    }

    // Ablation: Hilbert block size (the cache-oblivious point is that any
    // reasonable micro-tile works; tiled must be tuned).
    let n = if fast { 128 } else { 512 };
    let b = Matrix::random(n, n, 3, -1.0, 1.0);
    let c = Matrix::random(n, n, 4, -1.0, 1.0);
    let mut ablation = Table::new(vec!["tile", "hilbert GFLOP/s", "tiled GFLOP/s"]);
    for t in [8usize, 16, 32, 64, 128] {
        let mh = bench.run(&format!("matmul/hilbert_tile/{t}"), || matmul_hilbert(&b, &c, t));
        let mt = bench.run(&format!("matmul/tiled_tile/{t}"), || matmul_tiled(&b, &c, t));
        let fl = flops(n, n, n) as f64;
        ablation.row(vec![
            t.to_string(),
            format!("{:.2}", fl / mh.median.as_secs_f64() / 1e9),
            format!("{:.2}", fl / mt.median.as_secs_f64() / 1e9),
        ]);
    }

    println!("\n== §7 matmul ==");
    print!("{}", table.render());
    println!("\n== block-size ablation (n={n}) ==");
    print!("{}", ablation.render());
    bench.write_csv("reports/bench_matmul.csv").unwrap();
}
