"""L1 Pallas kernel: tiled pairwise squared distances.

The compute hot-spot of k-Means assignment (and of the similarity join's
refinement phase) as a Pallas kernel. TPU mapping of the paper's idea (see
DESIGN.md §Hardware-Adaptation): the (point-tile x centroid-tile) blocking
keeps both operand tiles resident in VMEM while the MXU computes the
cross-term as a matmul:

    ||x - c||^2 = ||x||^2 + ||c||^2 - 2 x.c

Grid: (n/TP, k/TC); each step produces one (TP, TC) output tile from a
(TP, D) point tile and a (TC, D) centroid tile. The Hilbert-order dispatch
of larger block batches lives in the Rust coordinator (L3); within one
dispatch the dense tile grid maximises VMEM reuse.

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; real-TPU perf is estimated analytically (EXPERIMENTS.md §Perf).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes: 8x128 keeps the f32 VMEM tiling of the TPU happy
# (8-sublane x 128-lane registers) while staying tiny enough for tests.
DEFAULT_TP = 128
DEFAULT_TC = 128


def _dist_kernel(x_ref, c_ref, o_ref):
    """One (TP, TC) tile: x_ref (TP, D), c_ref (TC, D)."""
    x = x_ref[...]
    c = c_ref[...]
    # Cross term on the MXU; norms on the VPU.
    cross = jax.lax.dot_general(
        x, c, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    xn = jnp.sum(x * x, axis=1, keepdims=True)
    cn = jnp.sum(c * c, axis=1, keepdims=True)
    o_ref[...] = xn + cn.T - 2.0 * cross


@functools.partial(jax.jit, static_argnames=("tp", "tc"))
def pairwise_sq_dists(points, centroids, tp=None, tc=None):
    """(n, d) x (k, d) -> (n, k) squared distances via the Pallas kernel.

    n must divide by tp and k by tc (the L2 model pads when needed).
    """
    n, d = points.shape
    k, d2 = centroids.shape
    assert d == d2, f"dim mismatch {d} vs {d2}"
    tp = min(n, DEFAULT_TP) if tp is None else tp
    tc = min(k, DEFAULT_TC) if tc is None else tc
    assert n % tp == 0, f"n={n} not divisible by tile {tp}"
    assert k % tc == 0, f"k={k} not divisible by tile {tc}"
    grid = (n // tp, k // tc)
    return pl.pallas_call(
        _dist_kernel,
        out_shape=jax.ShapeDtypeStruct((n, k), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tp, d), lambda i, j: (i, 0)),
            pl.BlockSpec((tc, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tp, tc), lambda i, j: (i, j)),
        interpret=True,
    )(points, centroids)
