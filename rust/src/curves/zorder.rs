//! Z-order (Morton / Lebesgue / N-order) by bit interleaving (§2.2, Fig 2).
//!
//! `ℤ(i,j)` interleaves the bits of `i` and `j`:
//! `c = ⟨i_L j_L … i_1 j_1 i_0 j_0⟩`. The paper notes hardware support via
//! BMI2 `PDEP`/`PEXT`; the portable magic-mask expansion below compiles to a
//! handful of shift/mask ops and is the standard software equivalent (the
//! `_part1by1`/`_unpart1by1` construction). [`spread`]/[`compact`] are the
//! stride-2 special case of the d-way mask ladder in
//! [`fastkey`](super::fastkey), which generalizes the same construction to
//! arbitrary dimension counts for the batched Nd key paths.

use super::SpaceFillingCurve;

/// Spread the 32 bits of `x` into the even bit positions of a u64
/// (software `PDEP(x, 0x5555…)`).
#[inline]
pub fn spread(x: u32) -> u64 {
    let mut x = x as u64;
    x = (x | (x << 16)) & 0x0000_FFFF_0000_FFFF;
    x = (x | (x << 8)) & 0x00FF_00FF_00FF_00FF;
    x = (x | (x << 4)) & 0x0F0F_0F0F_0F0F_0F0F;
    x = (x | (x << 2)) & 0x3333_3333_3333_3333;
    x = (x | (x << 1)) & 0x5555_5555_5555_5555;
    x
}

/// Inverse of [`spread`]: gather the even bit positions of `x` into a u32
/// (software `PEXT(x, 0x5555…)`).
#[inline]
pub fn compact(x: u64) -> u32 {
    let mut x = x & 0x5555_5555_5555_5555;
    x = (x | (x >> 1)) & 0x3333_3333_3333_3333;
    x = (x | (x >> 2)) & 0x0F0F_0F0F_0F0F_0F0F;
    x = (x | (x >> 4)) & 0x00FF_00FF_00FF_00FF;
    x = (x | (x >> 8)) & 0x0000_FFFF_0000_FFFF;
    x = (x | (x >> 16)) & 0x0000_0000_FFFF_FFFF;
    x as u32
}

/// The Z-order curve.
///
/// Digit convention (paper Fig 2, coordinate system top-down): the quadrant
/// order is `(0,0) → 0, (0,1) → 1, (1,0) → 2, (1,1) → 3`, i.e. the `i` bit
/// is the *high* bit of each four-adic output digit.
#[derive(Copy, Clone, Debug)]
pub struct ZOrder;

impl SpaceFillingCurve for ZOrder {
    const NAME: &'static str = "zorder";

    #[inline]
    fn order(i: u32, j: u32) -> u64 {
        (spread(i) << 1) | spread(j)
    }

    #[inline]
    fn coords(c: u64) -> (u32, u32) {
        (compact(c >> 1), compact(c))
    }

    /// Native window decomposition: the table-free quadrant descent
    /// (each order digit names its quadrant directly) at the smallest
    /// level covering the window.
    fn decompose_window(window: &crate::curves::engine::Window) -> Vec<std::ops::Range<u64>> {
        assert!(
            window.hi.0 < (1 << 31) && window.hi.1 < (1 << 31),
            "plane windows support coordinates below 2^31"
        );
        let level = 32 - (window.hi.0 | window.hi.1).leading_zeros();
        crate::curves::engine::decompose_zorder_2d(level, window)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::forall;

    #[test]
    fn spread_compact_roundtrip() {
        forall::<u32>("spread-compact", |&x| compact(spread(x)) == x);
    }

    #[test]
    fn spread_known_values() {
        assert_eq!(spread(0), 0);
        assert_eq!(spread(0b1), 0b1);
        assert_eq!(spread(0b11), 0b101);
        assert_eq!(spread(0b101), 0b10001);
        assert_eq!(spread(u32::MAX), 0x5555_5555_5555_5555);
    }

    #[test]
    fn fig2_quadrant_digits() {
        // Paper Fig 2 convention: (0,0)→0, (0,1)→1, (1,0)→2, (1,1)→3.
        assert_eq!(ZOrder::order(0, 0), 0);
        assert_eq!(ZOrder::order(0, 1), 1);
        assert_eq!(ZOrder::order(1, 0), 2);
        assert_eq!(ZOrder::order(1, 1), 3);
    }

    #[test]
    fn fig2_4x4_table() {
        // The level-2 Z-order over a 4×4 grid (paper Fig 2, right side).
        let expect: [[u64; 4]; 4] = [
            [0, 1, 4, 5],
            [2, 3, 6, 7],
            [8, 9, 12, 13],
            [10, 11, 14, 15],
        ];
        for i in 0..4 {
            for j in 0..4 {
                assert_eq!(ZOrder::order(i, j), expect[i as usize][j as usize]);
            }
        }
    }

    #[test]
    fn roundtrip_property() {
        forall::<(u32, u32)>("zorder-roundtrip", |&(i, j)| {
            ZOrder::coords(ZOrder::order(i, j)) == (i, j)
        });
    }

    #[test]
    fn bijective_on_prefix() {
        use std::collections::HashSet;
        let vals: HashSet<u64> = (0..32u32)
            .flat_map(|i| (0..32u32).map(move |j| ZOrder::order(i, j)))
            .collect();
        assert_eq!(vals.len(), 1024);
        assert_eq!(*vals.iter().max().unwrap(), 1023);
    }

    #[test]
    fn recursive_self_similarity() {
        // ℤ(2i, 2j) == 4·ℤ(i,j): each bisection step multiplies by 4.
        forall::<(u32, u32)>("zorder-selfsim", |&(i, j)| {
            let (i, j) = (i >> 1, j >> 1); // keep doubling in range
            ZOrder::order(2 * i, 2 * j) == 4 * ZOrder::order(i, j)
        });
    }

    #[test]
    fn max_coordinates_roundtrip() {
        let c = ZOrder::order(u32::MAX, u32::MAX);
        assert_eq!(c, u64::MAX);
        assert_eq!(ZOrder::coords(c), (u32::MAX, u32::MAX));
    }
}
